//! The baseline greedy edge-ordering (Algorithm 3): evaluates the Eq. (7)
//! objective for **every** frontier vertex at every step. Exponentially
//! clearer and polynomially slower than Algorithm 4 — Theorem 4 puts it at
//! `O(k_max²·|E|²·|V|²/k_min)` — so it exists purely as the ground-truth
//! oracle that [`super::geo`] is validated against on small graphs.

use super::objective::eval_partial_eq7;
use super::window::TailWindow;
use super::EdgeOrdering;
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::{EdgeId, VertexId};
use std::collections::BTreeSet;

/// Parameters (same semantics as [`super::geo::GeoConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct BaselineConfig {
    /// smallest anticipated k
    pub k_min: usize,
    /// largest anticipated k
    pub k_max: usize,
    /// two-hop admission window (None → ⌊|E|/k_max⌋, min 1)
    pub delta: Option<usize>,
    /// restart-vertex seed
    pub seed: u64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig { k_min: 2, k_max: 8, delta: None, seed: 42 }
    }
}

/// Run Algorithm 3. Only call on small graphs (≲ 200 edges).
pub fn order(g: &Graph, cfg: &BaselineConfig) -> EdgeOrdering {
    let n = g.num_vertices();
    let m = g.num_edges();
    if m == 0 {
        return EdgeOrdering::identity(0);
    }
    let delta = cfg.delta.unwrap_or(m / cfg.k_max).max(1);

    let mut ordered = vec![false; m];
    let mut perm: Vec<EdgeId> = Vec::with_capacity(m);
    let mut x_pairs: Vec<(u32, u32)> = Vec::with_capacity(m);
    let mut window = TailWindow::new(n, delta);
    let mut in_rest = vec![true; n];
    let mut rest_count = n;
    // frontier = V_rest ∩ V(X), BTreeSet for deterministic iteration
    let mut frontier: BTreeSet<VertexId> = BTreeSet::new();
    let mut rng = Rng::new(cfg.seed);
    let mut pool: Vec<VertexId> = (0..n as VertexId).collect();

    while rest_count > 0 {
        // --- greedy search (Alg 3 l.4-11)
        let v_min = if frontier.is_empty() {
            loop {
                let idx = rng.below_usize(pool.len());
                let v = pool.swap_remove(idx);
                if in_rest[v as usize] {
                    break v;
                }
            }
        } else {
            let mut best: Option<(u64, VertexId)> = None;
            for &v in &frontier {
                // X' = X + (N(v) \ X), neighbours ascending
                let mut xp = x_pairs.clone();
                for (u, eid) in g.neighbors(v) {
                    if !ordered[eid as usize] {
                        let e = g.edges()[eid as usize];
                        xp.push((e.u, e.v));
                    }
                    let _ = u;
                }
                let f_v = eval_partial_eq7(n, &xp, m as u64, cfg.k_min, cfg.k_max);
                if best.map(|(bf, bv)| (f_v, v) < (bf, bv)).unwrap_or(true) {
                    best = Some((f_v, v));
                }
            }
            best.unwrap().1
        };

        // --- assign new edge order (Alg 3 l.13-17; identical to Alg 4)
        for (u, eid) in g.neighbors(v_min) {
            if ordered[eid as usize] {
                continue;
            }
            ordered[eid as usize] = true;
            perm.push(eid);
            let e = g.edges()[eid as usize];
            x_pairs.push((e.u, e.v));
            window.push(e);
            for (w, eid2) in g.neighbors(u) {
                if ordered[eid2 as usize] {
                    continue;
                }
                if window.contains(w) {
                    ordered[eid2 as usize] = true;
                    perm.push(eid2);
                    let e2 = g.edges()[eid2 as usize];
                    x_pairs.push((e2.u, e2.v));
                    window.push(e2);
                    if in_rest[w as usize] {
                        frontier.insert(w);
                    }
                }
            }
            if in_rest[u as usize] {
                frontier.insert(u);
            }
        }

        in_rest[v_min as usize] = false;
        frontier.remove(&v_min);
        rest_count -= 1;
    }

    debug_assert_eq!(perm.len(), m);
    EdgeOrdering::new(perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::ordering::geo::{self, GeoConfig};
    use crate::ordering::objective::eval_eq1;
    use crate::ordering::random::random_edge_order;

    #[test]
    fn produces_full_permutation() {
        let g = erdos_renyi(24, 60, 3);
        let o = order(&g, &BaselineConfig::default());
        assert_eq!(o.len(), g.num_edges());
    }

    #[test]
    fn beats_random_on_objective() {
        let g = erdos_renyi(30, 120, 4);
        let base = order(&g, &BaselineConfig { k_min: 2, k_max: 4, ..Default::default() });
        let o_base = eval_eq1(&base.apply(&g), 2, 4);
        let o_rand = eval_eq1(&random_edge_order(&g, 7).apply(&g), 2, 4);
        assert!(o_base <= o_rand, "baseline {o_base} vs random {o_rand}");
    }

    /// Lemma 2 (the paper's equivalence claim) in its practical form: on
    /// graphs satisfying the lemma's assumptions reasonably well
    /// (|E| ≫ k_max, D[v] < |E|/k_max), the PQ-driven Algorithm 4 matches
    /// the exhaustive Algorithm 3 in objective value (small tolerance: the
    /// lemma's `w·ΔD − ΔM` approximation discards a ±ΔD term, so
    /// tie-region picks may differ without affecting quality).
    #[test]
    fn algorithm4_matches_algorithm3_quality() {
        for seed in [1u64, 2, 3] {
            let g = erdos_renyi(40, 240, seed); // d_avg 12 < |E|/k_max = 60
            let cfg3 = BaselineConfig { k_min: 2, k_max: 4, delta: Some(30), seed: 9 };
            let cfg4 =
                GeoConfig { k_min: 2, k_max: 4, delta: Some(30), seed: 9, ..Default::default() };
            let o3 = eval_eq1(&order(&g, &cfg3).apply(&g), 2, 4);
            let o4 = eval_eq1(&geo::order(&g, &cfg4).apply(&g), 2, 4);
            let rel = (o4 - o3).abs() / o3;
            assert!(rel < 0.05, "seed {seed}: alg3 {o3:.4} vs alg4 {o4:.4} (rel {rel:.4})");
        }
    }
}
