//! Log-bucketed latency/size histograms: O(1) lock-free recording into
//! atomic buckets, approximate quantiles (p50/p90/p99/max) from a
//! snapshot.
//!
//! Bucket layout (log-linear, the HdrHistogram shape):
//!
//! * values `0..64` land in 64 exact unit buckets;
//! * every power-of-two decade `[2^m, 2^(m+1))` for `m = 6..=63` is split
//!   into 8 equal sub-buckets.
//!
//! That is 64 + 58·8 = 528 buckets covering the whole `u64` range with a
//! relative quantile error of at most 12.5% (one sub-bucket width), which
//! is plenty for wall-time distributions spanning ns…minutes. Recording
//! is a handful of relaxed atomic RMWs, so a shared `&Histogram` can be
//! hammered from the `par` pool without locks; quantiles are computed
//! from an owned [`HistSnapshot`], never on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: 64 exact unit buckets + 8 sub-buckets for each of
/// the 58 power-of-two decades `[2^6, 2^64)`.
pub const NUM_BUCKETS: usize = 64 + 58 * 8;

/// Bucket index of a value — exact below 64, log-linear above.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < 64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as usize; // 6..=63
        64 + (msb - 6) * 8 + ((v >> (msb - 3)) & 7) as usize
    }
}

/// Largest value that lands in bucket `b` (inclusive upper bound).
#[inline]
fn bucket_upper(b: usize) -> u64 {
    if b < 64 {
        b as u64
    } else {
        let m = 6 + (b - 64) / 8; // decade: values in [2^m, 2^(m+1))
        let s = ((b - 64) % 8) as u64; // sub-bucket within the decade
        if m == 63 && s == 7 {
            u64::MAX
        } else {
            (1u64 << m) + ((s + 1) << (m - 3)) - 1
        }
    }
}

/// A concurrent log-bucketed histogram (see the module docs for the
/// bucket layout). `record` is O(1) and wait-free per call; `snapshot`
/// is O(buckets) and taken off the hot path.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// wrapping sum of recorded values (overflow is tolerated: the mean
    /// is advisory, the quantiles never consult the sum)
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Recorded sample count.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold another histogram's contents into this one.
    pub fn merge_from(&self, other: &Histogram) {
        for (b, ob) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = ob.load(Ordering::Relaxed);
            if v != 0 {
                b.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// An owned, consistent-enough copy for quantile math (bucket loads
    /// are relaxed; concurrent recorders may straddle the snapshot by a
    /// sample — fine for reporting).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// An owned point-in-time copy of a [`Histogram`], with quantile math.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    /// recorded sample count
    pub count: u64,
    /// wrapping sum of recorded values
    pub sum: u64,
    /// smallest recorded value (`u64::MAX` when empty)
    pub min: u64,
    /// largest recorded value (0 when empty)
    pub max: u64,
    buckets: Vec<u64>,
}

impl HistSnapshot {
    /// Were any samples recorded?
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of the recorded values (0.0 when empty; advisory — the sum
    /// wraps on overflow).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate `q`-quantile (`0.0..=1.0`): the inclusive upper bound
    /// of the bucket holding the rank-`⌈q·count⌉` sample, clamped to the
    /// exact observed `[min, max]`. Relative error ≤ 12.5% (one
    /// sub-bucket). Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_upper(b).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another snapshot into this one.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (b, &ob) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += ob;
        }
        self.count += other.count;
        self.sum = self.sum.wrapping_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_buckets_are_exact() {
        for v in 0..64u64 {
            assert_eq!(bucket_of(v), v as usize);
            assert_eq!(bucket_upper(v as usize), v);
        }
    }

    #[test]
    fn decade_boundaries() {
        // first sub-bucketed decade: [64, 128) in 8 sub-buckets of width 8
        assert_eq!(bucket_of(64), 64);
        assert_eq!(bucket_of(71), 64);
        assert_eq!(bucket_of(72), 65);
        assert_eq!(bucket_of(127), 71);
        assert_eq!(bucket_of(128), 72);
        assert_eq!(bucket_upper(64), 71);
        assert_eq!(bucket_upper(71), 127);
        // top of the range
        assert_eq!(bucket_of(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_upper(NUM_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn buckets_are_monotone_and_bound_their_values() {
        let samples: Vec<u64> = (0..2000u64)
            .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> (i % 60))
            .chain((0..64).map(|m| 1u64 << m))
            .chain([0, 1, 63, 64, 65, u64::MAX - 1, u64::MAX])
            .collect();
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for w in sorted.windows(2) {
            assert!(bucket_of(w[0]) <= bucket_of(w[1]), "{} vs {}", w[0], w[1]);
        }
        for &v in &samples {
            let b = bucket_of(v);
            assert!(b < NUM_BUCKETS);
            let upper = bucket_upper(b);
            assert!(upper >= v, "upper({b}) = {upper} < {v}");
            if v >= 64 {
                // one sub-bucket of slack: 2^(m-3) ≤ v/8
                assert!(upper - v <= v / 8, "bucket error beyond 12.5% at {v}");
            }
        }
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 1000);
        assert!((s.mean() - 500.5).abs() < 1e-9);
        let p50 = s.quantile(0.50);
        let p99 = s.quantile(0.99);
        assert!((500..=563).contains(&p50), "p50 = {p50}");
        assert!((990..=1000).contains(&p99), "p99 = {p99}");
        assert_eq!(s.quantile(1.0), 1000);
        assert!(s.quantile(0.0) >= 1);
    }

    #[test]
    fn merge_equals_single_histogram() {
        let all = Histogram::new();
        let evens = Histogram::new();
        let odds = Histogram::new();
        for v in 0..500u64 {
            all.record(v * 37 % 10_000);
            if v % 2 == 0 {
                evens.record(v * 37 % 10_000);
            } else {
                odds.record(v * 37 % 10_000);
            }
        }
        evens.merge_from(&odds);
        let (a, b) = (all.snapshot(), evens.snapshot());
        assert_eq!(a.count, b.count);
        assert_eq!(a.sum, b.sum);
        assert_eq!(a.min, b.min);
        assert_eq!(a.max, b.max);
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), b.quantile(q), "q = {q}");
        }
        // snapshot-level merge agrees too
        let mut c = odds.snapshot();
        c.merge(&evens.snapshot());
        assert!(c.count > b.count); // odds were folded into evens already
    }

    #[test]
    fn empty_histogram_is_sane() {
        let s = Histogram::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
    }
}
