//! Compressed sparse row adjacency over an [`EdgeList`].
//!
//! Each undirected edge appears in both endpoints' adjacency rows, tagged
//! with its edge id so that ordering algorithms can mark edges as assigned.

use super::edgelist::EdgeList;
use crate::{EdgeId, VertexId};

/// CSR adjacency: `offsets[v]..offsets[v+1]` indexes into parallel arrays
/// `nbr` (neighbour vertex) and `eid` (edge id in the edge list).
#[derive(Clone, Debug)]
pub struct Csr {
    offsets: Vec<u64>,
    nbr: Vec<VertexId>,
    eid: Vec<EdgeId>,
}

impl Csr {
    /// Build from an edge list over `n` vertices (two passes, O(|V|+|E|)).
    pub fn build(n: usize, edges: &EdgeList) -> Csr {
        let mut counts = vec![0u64; n + 1];
        for e in edges.iter() {
            counts[e.u as usize + 1] += 1;
            counts[e.v as usize + 1] += 1;
        }
        for i in 1..=n {
            counts[i] += counts[i - 1];
        }
        let offsets = counts.clone();
        let m2 = *offsets.last().unwrap_or(&0) as usize;
        let mut nbr = vec![0 as VertexId; m2];
        let mut eid = vec![0 as EdgeId; m2];
        let mut cursor = offsets.clone();
        for (id, e) in edges.iter().enumerate() {
            let cu = cursor[e.u as usize] as usize;
            nbr[cu] = e.v;
            eid[cu] = id as EdgeId;
            cursor[e.u as usize] += 1;
            let cv = cursor[e.v as usize] as usize;
            nbr[cv] = e.u;
            eid[cv] = id as EdgeId;
            cursor[e.v as usize] += 1;
        }
        // Sort each row by neighbour id for deterministic traversal order
        // (the paper: "each neighbor edge is accessed in ascending order of
        // the destination vertex id").
        let mut csr = Csr { offsets, nbr, eid };
        csr.sort_rows();
        csr
    }

    fn sort_rows(&mut self) {
        for v in 0..self.num_vertices() {
            let lo = self.offsets[v] as usize;
            let hi = self.offsets[v + 1] as usize;
            // sort (nbr, eid) jointly by nbr then eid
            let mut row: Vec<(VertexId, EdgeId)> = (lo..hi)
                .map(|i| (self.nbr[i], self.eid[i]))
                .collect();
            row.sort_unstable();
            for (off, (n, e)) in row.into_iter().enumerate() {
                self.nbr[lo + off] = n;
                self.eid[lo + off] = e;
            }
        }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Iterate `(neighbour, edge id)` in ascending neighbour order.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> impl Iterator<Item = (VertexId, EdgeId)> + '_ {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        (lo..hi).map(move |i| (self.nbr[i], self.eid[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edgelist::Edge;

    fn small() -> (usize, EdgeList) {
        // triangle 0-1-2 plus pendant 3 on 2
        (
            4,
            EdgeList::from_vec(vec![
                Edge::new(0, 1),
                Edge::new(1, 2),
                Edge::new(2, 0),
                Edge::new(2, 3),
            ]),
        )
    }

    #[test]
    fn degrees() {
        let (n, el) = small();
        let csr = Csr::build(n, &el);
        assert_eq!(csr.degree(0), 2);
        assert_eq!(csr.degree(1), 2);
        assert_eq!(csr.degree(2), 3);
        assert_eq!(csr.degree(3), 1);
    }

    #[test]
    fn neighbors_sorted_with_edge_ids() {
        let (n, el) = small();
        let csr = Csr::build(n, &el);
        let nb: Vec<_> = csr.neighbors(2).collect();
        assert_eq!(nb, vec![(0, 2), (1, 1), (3, 3)]);
    }

    #[test]
    fn isolated_vertices_allowed() {
        let el = EdgeList::from_vec(vec![Edge::new(0, 1)]);
        let csr = Csr::build(5, &el);
        assert_eq!(csr.degree(4), 0);
        assert_eq!(csr.neighbors(3).count(), 0);
    }

    #[test]
    fn total_adjacency_is_twice_edges() {
        let (n, el) = small();
        let csr = Csr::build(n, &el);
        let total: usize = (0..n as VertexId).map(|v| csr.degree(v)).sum();
        assert_eq!(total, 2 * el.len());
    }
}
