//! Migration planning: diff two assignments into per-(source, dest) edge
//! transfer lists, verify conservation, and produce the byte volumes the
//! network emulator prices.

use crate::partition::EdgePartition;
use crate::PartitionId;
use std::collections::HashMap;

/// A planned transfer of a contiguous batch of edges between two workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Transfer {
    /// sending partition
    pub from: PartitionId,
    /// receiving partition
    pub to: PartitionId,
    /// edge ids to move
    pub edges: Vec<u64>,
}

/// A full migration plan between two partitionings of the same edge set.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    /// transfers grouped by (from, to)
    pub transfers: Vec<Transfer>,
}

impl MigrationPlan {
    /// Diff `old` → `new` (must cover the same edge ids).
    pub fn diff(old: &EdgePartition, new: &EdgePartition) -> MigrationPlan {
        assert_eq!(old.assign.len(), new.assign.len(), "edge sets differ");
        let mut buckets: HashMap<(PartitionId, PartitionId), Vec<u64>> = HashMap::new();
        for (eid, (&o, &n)) in old.assign.iter().zip(new.assign.iter()).enumerate() {
            if o != n {
                buckets.entry((o, n)).or_default().push(eid as u64);
            }
        }
        let mut transfers: Vec<Transfer> = buckets
            .into_iter()
            .map(|((from, to), edges)| Transfer { from, to, edges })
            .collect();
        transfers.sort_by_key(|t| (t.from, t.to));
        MigrationPlan { transfers }
    }

    /// Total migrated edges.
    pub fn migrated_edges(&self) -> u64 {
        self.transfers.iter().map(|t| t.edges.len() as u64).sum()
    }

    /// Bytes on the wire for a given per-edge payload: 8 B of structure
    /// (two u32 endpoints) plus `value_bytes` of application state
    /// (Fig 14 sweeps 0–32 B).
    pub fn bytes(&self, value_bytes: u64) -> u64 {
        self.migrated_edges() * (8 + value_bytes)
    }

    /// Per-sender byte volumes (the network emulator serializes per link).
    pub fn per_sender_bytes(&self, value_bytes: u64, k: usize) -> Vec<u64> {
        let mut out = vec![0u64; k];
        for t in &self.transfers {
            out[t.from as usize] += t.edges.len() as u64 * (8 + value_bytes);
        }
        out
    }

    /// Check conservation: every edge appears at most once as moved, and
    /// destinations match `new`.
    pub fn validate(&self, old: &EdgePartition, new: &EdgePartition) -> bool {
        let mut seen = std::collections::HashSet::new();
        for t in &self.transfers {
            for &e in &t.edges {
                if !seen.insert(e) {
                    return false;
                }
                if old.assign[e as usize] != t.from || new.assign[e as usize] != t.to {
                    return false;
                }
            }
        }
        // edges not in plan must be unchanged
        let planned = seen.len();
        let changed = old
            .assign
            .iter()
            .zip(new.assign.iter())
            .filter(|(o, n)| o != n)
            .count();
        planned == changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cep::Cep;
    use crate::util::proptest::check;

    #[test]
    fn diff_of_identical_is_empty() {
        let p = EdgePartition::new(3, vec![0, 1, 2, 0, 1]);
        let plan = MigrationPlan::diff(&p, &p);
        assert_eq!(plan.migrated_edges(), 0);
        assert!(plan.validate(&p, &p));
    }

    #[test]
    fn diff_tracks_moves() {
        let old = EdgePartition::new(2, vec![0, 0, 1, 1]);
        let new = EdgePartition::new(2, vec![0, 1, 1, 0]);
        let plan = MigrationPlan::diff(&old, &new);
        assert_eq!(plan.migrated_edges(), 2);
        assert!(plan.validate(&old, &new));
        assert_eq!(plan.bytes(0), 16);
        assert_eq!(plan.bytes(8), 32);
    }

    #[test]
    fn plan_validates_for_random_cep_rescale() {
        check(0x9147, 24, |rng| {
            let m = 1000 + rng.below_usize(5000);
            let k0 = 2 + rng.below_usize(20);
            let k1 = 2 + rng.below_usize(20);
            let old = EdgePartition::from_cep(&Cep::new(m, k0));
            let new = EdgePartition::from_cep(&Cep::new(m, k1));
            let plan = MigrationPlan::diff(&old, &new);
            assert!(plan.validate(&old, &new));
            let per = plan.per_sender_bytes(4, k0.max(k1));
            assert_eq!(per.iter().sum::<u64>(), plan.bytes(4));
        });
    }
}
