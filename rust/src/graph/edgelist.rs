//! Flat edge-list storage.

use crate::VertexId;

/// A single undirected edge `{u, v}` stored as an ordered pair for
/// determinism (`u <= v` is *not* required: generators may emit either
/// orientation; deduplication canonicalizes before insertion).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Edge {
    /// one endpoint
    pub u: VertexId,
    /// the other endpoint
    pub v: VertexId,
}

impl Edge {
    /// Construct an edge.
    #[inline]
    pub fn new(u: VertexId, v: VertexId) -> Edge {
        Edge { u, v }
    }

    /// Canonical orientation (`min, max`) — used for dedup keys.
    #[inline]
    pub fn canonical(self) -> (VertexId, VertexId) {
        if self.u <= self.v {
            (self.u, self.v)
        } else {
            (self.v, self.u)
        }
    }

    /// The endpoint that is not `x` (panics if `x` is not an endpoint).
    #[inline]
    pub fn other(self, x: VertexId) -> VertexId {
        if self.u == x {
            self.v
        } else {
            debug_assert_eq!(self.v, x);
            self.u
        }
    }
}

/// Contiguous edge array. Positions in this array *are* the edge ids the
/// rest of the crate uses; an "ordering" is a permutation of this array.
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    edges: Vec<Edge>,
}

impl EdgeList {
    /// Wrap a vector of edges.
    pub fn from_vec(edges: Vec<Edge>) -> EdgeList {
        EdgeList { edges }
    }

    /// Number of edges.
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Iterate edges in id order.
    pub fn iter(&self) -> std::slice::Iter<'_, Edge> {
        self.edges.iter()
    }

    /// Raw slice.
    pub fn as_slice(&self) -> &[Edge] {
        &self.edges
    }
}

impl std::ops::Index<usize> for EdgeList {
    type Output = Edge;
    #[inline]
    fn index(&self, i: usize) -> &Edge {
        &self.edges[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_orders_endpoints() {
        assert_eq!(Edge::new(5, 2).canonical(), (2, 5));
        assert_eq!(Edge::new(2, 5).canonical(), (2, 5));
    }

    #[test]
    fn other_endpoint() {
        let e = Edge::new(3, 7);
        assert_eq!(e.other(3), 7);
        assert_eq!(e.other(7), 3);
    }

    #[test]
    fn indexing() {
        let el = EdgeList::from_vec(vec![Edge::new(0, 1), Edge::new(1, 2)]);
        assert_eq!(el.len(), 2);
        assert_eq!(el[1], Edge::new(1, 2));
    }
}
