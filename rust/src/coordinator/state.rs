//! Cluster state tracking: epochs, partition-map versions and an audit
//! log of every scaling action (what a production control plane would
//! persist for observability).

use std::time::Duration;

/// One completed scaling action.
#[derive(Clone, Debug)]
pub struct ScaleRecord {
    /// epoch after the action
    pub epoch: u64,
    /// partition count before
    pub from_k: usize,
    /// partition count after
    pub to_k: usize,
    /// edges migrated
    pub migrated_edges: u64,
    /// wall/emulated duration of the whole action
    pub duration: Duration,
}

/// Mutable cluster state.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// monotonically increasing partition-map version
    pub epoch: u64,
    /// current partition count
    pub k: usize,
    /// audit log
    pub history: Vec<ScaleRecord>,
}

impl ClusterState {
    /// Fresh cluster at `k` partitions, epoch 0.
    pub fn new(k: usize) -> ClusterState {
        ClusterState { epoch: 0, k, history: Vec::new() }
    }

    /// Record a completed scale action and bump the epoch.
    pub fn record_scale(&mut self, to_k: usize, migrated: u64, duration: Duration) {
        self.epoch += 1;
        self.history.push(ScaleRecord {
            epoch: self.epoch,
            from_k: self.k,
            to_k,
            migrated_edges: migrated,
            duration,
        });
        self.k = to_k;
    }

    /// Total migrated edges across the run.
    pub fn total_migrated(&self) -> u64 {
        self.history.iter().map(|r| r.migrated_edges).sum()
    }

    /// Total time spent scaling.
    pub fn total_scale_time(&self) -> Duration {
        self.history.iter().map(|r| r.duration).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_and_totals() {
        let mut s = ClusterState::new(4);
        s.record_scale(5, 1000, Duration::from_millis(10));
        s.record_scale(6, 2000, Duration::from_millis(20));
        assert_eq!(s.epoch, 2);
        assert_eq!(s.k, 6);
        assert_eq!(s.total_migrated(), 3000);
        assert_eq!(s.total_scale_time(), Duration::from_millis(30));
        assert_eq!(s.history[0].from_k, 4);
        assert_eq!(s.history[1].from_k, 5);
    }
}
