//! **Hybrid-Ginger** — PowerLyra's differentiated hybrid-cut (Chen et al.,
//! TOPC'19), simplified.
//!
//! Hybrid-cut treats low-degree and high-degree vertices differently:
//! edges anchored at a low-degree vertex are co-located by hashing that
//! vertex (low-cut), while edges of high-degree vertices are spread by
//! hashing the *other* endpoint (high-cut). Ginger adds a heuristic
//! balance-aware placement for the low-degree side, which we keep as a
//! least-loaded tie-break between the two endpoint hashes.

use super::EdgePartition;
use crate::graph::Graph;
use crate::util::rng::mix64;
use crate::PartitionId;

/// Degree threshold separating low- from high-degree vertices (PowerLyra
/// defaults to ~100 on billion-edge graphs; scaled to our graph sizes).
pub fn default_threshold(g: &Graph) -> usize {
    (4.0 * (2.0 * g.num_edges() as f64 / g.num_vertices().max(1) as f64)).ceil() as usize
}

/// Hybrid-Ginger-style partitioning with the default threshold.
pub fn partition(g: &Graph, k: usize) -> EdgePartition {
    partition_with_threshold(g, k, default_threshold(g))
}

/// Hybrid-Ginger-style partitioning with explicit threshold.
pub fn partition_with_threshold(g: &Graph, k: usize, theta: usize) -> EdgePartition {
    let mut sizes = vec![0u64; k];
    let hash_to = |v: u32| (mix64(v as u64) % k as u64) as PartitionId;
    let assign = g
        .edges()
        .iter()
        .map(|e| {
            let (du, dv) = (g.degree(e.u), g.degree(e.v));
            let p = match (du <= theta, dv <= theta) {
                // low/low: Ginger balance heuristic — the lighter of the
                // two endpoint-hash partitions
                (true, true) => {
                    let (a, b) = (hash_to(e.u), hash_to(e.v));
                    if sizes[a as usize] <= sizes[b as usize] {
                        a
                    } else {
                        b
                    }
                }
                // low/high: anchor at the low-degree endpoint (low-cut)
                (true, false) => hash_to(e.u),
                (false, true) => hash_to(e.v),
                // high/high: spread deterministically by canonical pair
                (false, false) => {
                    let (a, b) = e.canonical();
                    (mix64(((a as u64) << 32) | b as u64) % k as u64) as PartitionId
                }
            };
            sizes[p as usize] += 1;
            p
        })
        .collect();
    EdgePartition::new(k, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{rmat, RmatParams};
    use crate::partition::hash1d;
    use crate::partition::quality::{edge_balance, replication_factor};

    #[test]
    fn beats_1d_with_reasonable_balance() {
        let g = rmat(&RmatParams { scale: 11, edge_factor: 12, ..Default::default() }, 6);
        let p = partition(&g, 16);
        let rf = replication_factor(&g, &p);
        let rf_1d = replication_factor(&g, &hash1d::partition(&g, 16));
        assert!(rf < rf_1d, "ginger {rf} vs 1d {rf_1d}");
        // paper's Table 6 shows Hybrid Ginger EB around 1.1-1.4
        assert!(edge_balance(&p) < 1.6, "eb={}", edge_balance(&p));
    }

    #[test]
    fn threshold_extremes() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 6, ..Default::default() }, 7);
        // theta = ∞ → all vertices "low": degenerates to balance-greedy hash
        let all_low = partition_with_threshold(&g, 8, usize::MAX);
        // theta = 0 → all "high": canonical-pair hash (1D-like)
        let all_high = partition_with_threshold(&g, 8, 0);
        assert!(replication_factor(&g, &all_low) <= replication_factor(&g, &all_high));
    }
}
