//! Synthetic spot-instance traces — the motivating scenario of §1: VMs
//! appear when spare capacity exists and are preempted without warning.
//! Generated as a seeded Markov chain over capacity so experiments are
//! reproducible.

use crate::scaling::scenario::{ScaleEvent, Scenario};
use crate::util::rng::Rng;

/// One infrastructure event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpotEvent {
    /// a VM became available → scale out by one
    Provision,
    /// a VM was preempted → scale in by one
    Preempt,
}

/// A timed trace of events over application iterations.
#[derive(Clone, Debug)]
pub struct SpotTrace {
    /// `(iteration, event)` pairs, iteration-sorted
    pub events: Vec<(u32, SpotEvent)>,
    /// lower bound on cluster size the trace respects
    pub k_min: usize,
    /// upper bound
    pub k_max: usize,
}

impl SpotTrace {
    /// Generate a trace: every `period` iterations the market flips a
    /// biased coin; capacity does a bounded random walk in `[k_min, k_max]`.
    pub fn generate(
        k_start: usize,
        k_min: usize,
        k_max: usize,
        total_iters: u32,
        period: u32,
        seed: u64,
    ) -> SpotTrace {
        assert!(k_min >= 1 && k_min <= k_start && k_start <= k_max);
        let mut rng = Rng::new(seed);
        let mut k = k_start;
        let mut events = Vec::new();
        let mut it = period;
        while it < total_iters {
            // drift towards the middle of the band, as spot markets revert
            let mid = (k_min + k_max) as f64 / 2.0;
            let p_up = if (k as f64) < mid { 0.62 } else { 0.38 };
            if rng.chance(p_up) {
                if k < k_max {
                    k += 1;
                    events.push((it, SpotEvent::Provision));
                }
            } else if k > k_min {
                k -= 1;
                events.push((it, SpotEvent::Preempt));
            }
            it += period;
        }
        SpotTrace { events, k_min, k_max }
    }

    /// Script the trace as a [`Scenario`]: one scale event per market
    /// flip, plus a per-iteration price trace derived from the same walk
    /// (scarcer capacity → higher price), so price-aware policies sense
    /// the market the script reacts to. Named `"spot-market"`.
    pub fn to_scenario(&self, k_start: usize, total_iterations: u32) -> Scenario {
        let mut k = k_start;
        let mut events = Vec::new();
        for (it, e) in &self.events {
            match e {
                SpotEvent::Provision => k += 1,
                SpotEvent::Preempt => k -= 1,
            }
            events.push(ScaleEvent { at_iteration: *it, target_k: k });
        }
        // price ∝ scarcity: map capacity k ∈ [k_min, k_max] onto
        // [1.0, 2.0], higher when the market holds fewer VMs
        let span = (self.k_max - self.k_min).max(1) as f64;
        let price_of = |k: usize| 1.0 + (self.k_max - k) as f64 / span;
        let mut prices = Vec::with_capacity(total_iterations as usize);
        let mut cur = k_start;
        let mut next = 0;
        for it in 0..total_iterations {
            while next < self.events.len() && self.events[next].0 == it {
                match self.events[next].1 {
                    SpotEvent::Provision => cur += 1,
                    SpotEvent::Preempt => cur -= 1,
                }
                next += 1;
            }
            prices.push(price_of(cur));
        }
        Scenario {
            name: "spot-market".into(),
            initial_k: k_start,
            events,
            churn: Vec::new(),
            prices,
            total_iterations,
        }
    }

    /// Resulting k sequence starting from `k_start` (for tests/plots).
    pub fn k_sequence(&self, k_start: usize) -> Vec<usize> {
        let mut k = k_start;
        let mut out = vec![k];
        for (_, e) in &self.events {
            match e {
                SpotEvent::Provision => k += 1,
                SpotEvent::Preempt => k -= 1,
            }
            out.push(k);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_bounds() {
        let t = SpotTrace::generate(8, 4, 16, 10_000, 10, 7);
        for k in t.k_sequence(8) {
            assert!((4..=16).contains(&k));
        }
        assert!(!t.events.is_empty());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SpotTrace::generate(8, 4, 16, 1000, 10, 1);
        let b = SpotTrace::generate(8, 4, 16, 1000, 10, 1);
        assert_eq!(a.events, b.events);
        let c = SpotTrace::generate(8, 4, 16, 1000, 10, 2);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn to_scenario_scripts_the_walk_and_prices_scarcity() {
        let t = SpotTrace::generate(8, 4, 16, 500, 10, 7);
        let s = t.to_scenario(8, 500);
        assert_eq!(s.initial_k, 8);
        assert_eq!(s.events.len(), t.events.len());
        assert_eq!(s.total_iterations, 500);
        assert_eq!(s.prices.len(), 500);
        // the scripted targets replay the k walk exactly
        let ks: Vec<usize> = s.events.iter().map(|e| e.target_k).collect();
        assert_eq!(ks, t.k_sequence(8)[1..].to_vec());
        // prices track scarcity within [1, 2] and move when k moves
        assert!(s.prices.iter().all(|p| (1.0..=2.0).contains(p)));
        let first_flip = t.events[0].0 as usize;
        assert_ne!(s.prices[first_flip], s.prices[first_flip.saturating_sub(1)]);
    }

    #[test]
    fn events_are_time_ordered() {
        let t = SpotTrace::generate(6, 2, 12, 5000, 25, 3);
        for w in t.events.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }
}
