//! Dynamic-scaling scenarios (§6.4.2): **ScaleOut** adds one partition
//! every `period` iterations (26 → 36 in the paper), **ScaleIn** removes
//! one (36 → 26). Generic over the step sequence so examples can also run
//! spot-market traces.
//!
//! Scenarios also carry **churn events** — batched edge
//! insertions/deletions fired between application iterations — so the
//! driver ([`crate::coordinator::Controller::drive`], which selects the
//! streaming substrate whenever a scenario carries churn) can
//! script interleaved churn + rescale workloads. When a churn and a scale
//! event share an iteration, churn applies first (the rescale sees the
//! mutated edge-id space).

/// One scripted scaling event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScaleEvent {
    /// fires after this many completed application iterations
    pub at_iteration: u32,
    /// target partition count
    pub target_k: usize,
}

/// One scripted churn event: a mutation batch of the given shape is
/// generated (seeded) and ingested before the iteration runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    /// fires before this iteration's application step
    pub at_iteration: u32,
    /// edge insertions in the batch
    pub inserts: u32,
    /// edge deletions in the batch
    pub deletes: u32,
}

/// A scripted scenario: initial k plus sequences of scale and churn
/// events, optionally annotated with a per-iteration price trace
/// ([`Scenario::with_prices`]) the SLO policy can sense.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// descriptive name ("scale-out", "churn+scale-out", ...)
    pub name: String,
    /// starting partition count
    pub initial_k: usize,
    /// scale events in firing order
    pub events: Vec<ScaleEvent>,
    /// churn events in firing order (empty for the static scenarios)
    pub churn: Vec<ChurnEvent>,
    /// spot price per iteration ($/partition-hour or any consistent
    /// unit); indexed by iteration, clamped to the last entry, empty =
    /// price 0 everywhere. Pure sensor input — prices never fire events
    pub prices: Vec<f64>,
    /// total application iterations to run
    pub total_iterations: u32,
}

impl Scenario {
    /// Paper ScaleOut: k0 → k0+steps, one partition every `period` iters.
    pub fn scale_out(k0: usize, steps: usize, period: u32) -> Scenario {
        let events = (1..=steps)
            .map(|s| ScaleEvent { at_iteration: s as u32 * period, target_k: k0 + s })
            .collect();
        Scenario {
            name: format!("scale-out {k0}->{}", k0 + steps),
            initial_k: k0,
            events,
            churn: Vec::new(),
            prices: Vec::new(),
            total_iterations: (steps as u32 + 1) * period,
        }
    }

    /// Paper ScaleIn: k0 → k0−steps.
    pub fn scale_in(k0: usize, steps: usize, period: u32) -> Scenario {
        let events = (1..=steps)
            .map(|s| ScaleEvent { at_iteration: s as u32 * period, target_k: k0 - s })
            .collect();
        Scenario {
            name: format!("scale-in {k0}->{}", k0 - steps),
            initial_k: k0,
            events,
            churn: Vec::new(),
            prices: Vec::new(),
            total_iterations: (steps as u32 + 1) * period,
        }
    }

    /// A steady scenario: `k` partitions, no scale or churn events — the
    /// harness for workloads that only exercise superstep-time policies
    /// (e.g. the skew-aware boundary rebalancer).
    pub fn steady(k: usize, iterations: u32) -> Scenario {
        Scenario {
            name: format!("steady k={k}"),
            initial_k: k,
            events: Vec::new(),
            churn: Vec::new(),
            prices: Vec::new(),
            total_iterations: iterations,
        }
    }

    /// The paper's exact §6.4.2 pair at reduced scale: (out, in).
    pub fn paper_pair(k_lo: usize, k_hi: usize, period: u32) -> (Scenario, Scenario) {
        (
            Scenario::scale_out(k_lo, k_hi - k_lo, period),
            Scenario::scale_in(k_hi, k_hi - k_lo, period),
        )
    }

    /// Sprinkle a churn event of the given shape every `every` iterations
    /// (starting at iteration `every`), on top of whatever scale events the
    /// scenario already scripts.
    pub fn with_churn(mut self, every: u32, inserts: u32, deletes: u32) -> Scenario {
        assert!(every > 0, "churn period must be positive");
        let mut it = every;
        while it < self.total_iterations {
            self.churn.push(ChurnEvent { at_iteration: it, inserts, deletes });
            it += every;
        }
        self.name = format!("{} +churn(+{inserts}/-{deletes} every {every})", self.name);
        self
    }

    /// The streaming benchmark scenario: a paper ScaleOut with churn
    /// batches interleaved between the scale events.
    pub fn interleaved(
        k0: usize,
        steps: usize,
        period: u32,
        inserts: u32,
        deletes: u32,
    ) -> Scenario {
        Scenario::scale_out(k0, steps, period).with_churn(period.max(2) / 2, inserts, deletes)
    }

    /// A flash crowd: `pre` calm iterations at `k0`, then a burst window
    /// of `burst` iterations where every iteration ingests `inserts`
    /// edges (insert-only — a traffic spike, not turnover), then `post`
    /// iterations of decay churn at one tenth of the burst rate. No
    /// scripted scale events: the load change is the whole point, and a
    /// scaling policy (or an oracle script layered on top) must react.
    pub fn flash_crowd(k0: usize, pre: u32, burst: u32, post: u32, inserts: u32) -> Scenario {
        assert!(burst > 0, "a flash crowd needs a burst window");
        let mut churn = Vec::new();
        for it in pre..pre + burst {
            churn.push(ChurnEvent { at_iteration: it, inserts, deletes: 0 });
        }
        let decay = (inserts / 10).max(1);
        for it in pre + burst..pre + burst + post {
            churn.push(ChurnEvent { at_iteration: it, inserts: decay, deletes: decay });
        }
        Scenario {
            name: format!("flash-crowd k={k0} +{inserts}x{burst}"),
            initial_k: k0,
            events: Vec::new(),
            churn,
            prices: Vec::new(),
            total_iterations: pre + burst + post,
        }
    }

    /// Annotate the scenario with a per-iteration price trace (sensor
    /// input for price-aware policies; see [`Scenario::price_at`]).
    pub fn with_prices(mut self, prices: Vec<f64>) -> Scenario {
        self.prices = prices;
        self
    }

    /// Scale event scheduled at iteration `it`, if any.
    pub fn event_at(&self, it: u32) -> Option<&ScaleEvent> {
        self.events.iter().find(|e| e.at_iteration == it)
    }

    /// Churn event scheduled at iteration `it`, if any.
    pub fn churn_at(&self, it: u32) -> Option<&ChurnEvent> {
        self.churn.iter().find(|e| e.at_iteration == it)
    }

    /// Total scripted insertions.
    pub fn total_inserts(&self) -> u64 {
        self.churn.iter().map(|c| c.inserts as u64).sum()
    }

    /// Total scripted deletions.
    pub fn total_deletes(&self) -> u64 {
        self.churn.iter().map(|c| c.deletes as u64).sum()
    }

    /// Spot price at iteration `it`: the trace entry, clamped to the
    /// last one past the end; 0.0 when no trace is attached.
    pub fn price_at(&self, it: u32) -> f64 {
        match self.prices.get(it as usize) {
            Some(p) => *p,
            None => self.prices.last().copied().unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_out_schedule() {
        let s = Scenario::scale_out(26, 10, 10);
        assert_eq!(s.initial_k, 26);
        assert_eq!(s.events.len(), 10);
        assert_eq!(s.events[0], ScaleEvent { at_iteration: 10, target_k: 27 });
        assert_eq!(s.events[9], ScaleEvent { at_iteration: 100, target_k: 36 });
        assert_eq!(s.total_iterations, 110);
        assert!(s.churn.is_empty());
    }

    #[test]
    fn scale_in_schedule() {
        let s = Scenario::scale_in(36, 10, 10);
        assert_eq!(s.events[0].target_k, 35);
        assert_eq!(s.events[9].target_k, 26);
    }

    #[test]
    fn steady_has_no_events() {
        let s = Scenario::steady(6, 12);
        assert_eq!(s.initial_k, 6);
        assert_eq!(s.total_iterations, 12);
        assert!(s.events.is_empty() && s.churn.is_empty());
        assert!((0..12).all(|it| s.event_at(it).is_none() && s.churn_at(it).is_none()));
    }

    #[test]
    fn flash_crowd_shapes_burst_and_decay() {
        let s = Scenario::flash_crowd(3, 4, 3, 5, 200);
        assert_eq!(s.initial_k, 3);
        assert!(s.events.is_empty(), "the policy, not the script, must react");
        assert_eq!(s.total_iterations, 12);
        // calm window: no churn
        assert!((0..4).all(|it| s.churn_at(it).is_none()));
        // burst window: insert-only spikes
        for it in 4..7 {
            let c = s.churn_at(it).unwrap();
            assert_eq!((c.inserts, c.deletes), (200, 0));
        }
        // decay window: one tenth, balanced turnover
        for it in 7..12 {
            let c = s.churn_at(it).unwrap();
            assert_eq!((c.inserts, c.deletes), (20, 20));
        }
    }

    #[test]
    fn price_trace_clamps_to_last_entry() {
        let s = Scenario::steady(4, 10);
        assert_eq!(s.price_at(0), 0.0, "no trace, price 0 everywhere");
        let s = s.with_prices(vec![1.0, 2.5, 0.5]);
        assert_eq!(s.price_at(0), 1.0);
        assert_eq!(s.price_at(1), 2.5);
        assert_eq!(s.price_at(2), 0.5);
        assert_eq!(s.price_at(9), 0.5, "clamped to the last entry");
    }

    #[test]
    fn event_lookup() {
        let s = Scenario::scale_out(4, 2, 5);
        assert!(s.event_at(5).is_some());
        assert!(s.event_at(6).is_none());
    }

    #[test]
    fn churn_schedule_interleaves_with_scaling() {
        let s = Scenario::interleaved(4, 2, 6, 50, 10);
        // scale at 6 and 12; churn every 3 → 3, 6, 9, 12, 15
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.churn.len(), 5);
        assert_eq!(
            s.churn_at(3),
            Some(&ChurnEvent { at_iteration: 3, inserts: 50, deletes: 10 })
        );
        // iteration 6 hosts both kinds of events
        assert!(s.event_at(6).is_some() && s.churn_at(6).is_some());
        assert_eq!(s.total_inserts(), 250);
        assert_eq!(s.total_deletes(), 50);
    }

    #[test]
    fn with_churn_composes_with_any_scenario() {
        let s = Scenario::scale_in(6, 2, 4).with_churn(4, 7, 3);
        assert_eq!(s.churn.len(), 2); // iterations 4 and 8 (< 12)
        assert!(s.name.contains("churn"));
        assert!(s.churn_at(4).is_some());
        assert!(s.churn_at(5).is_none());
    }
}
