//! Graph substrate: edge-list / CSR storage, synthetic generators,
//! dataset registry, IO and degree statistics.
//!
//! The whole crate operates on *undirected, unweighted* graphs stored as an
//! explicit edge list (the object GEO orders and CEP slices) plus an
//! adjacency index ([`csr::Csr`]) for neighbourhood queries.

pub mod builder;
pub mod csr;
pub mod datasets;
pub mod edgelist;
pub mod generators;
pub mod io;
pub mod paged;
pub mod stats;

pub use csr::Csr;
pub use edgelist::{Edge, EdgeList};
pub use paged::{PagedConfig, PagedEdges, PagedStats};

use crate::{EdgeId, VertexId};

/// Random access to an edge list by edge id — the minimal read surface the
/// engine's mirror layout needs. [`Graph`] implements it over its canonical
/// edge list; [`crate::stream::StagedGraph`] implements it over
/// `base + staging tail` without ever materializing the combined list, so
/// the streaming path can rebuild touched partitions after a churn batch
/// with no O(m) copy.
pub trait EdgeSource {
    /// Number of vertices (dense id space `0..n`).
    fn num_vertices(&self) -> usize;

    /// Number of addressable edge ids (for staged sources this is the
    /// *physical* count including tombstoned edges).
    fn num_edges(&self) -> usize;

    /// Endpoints of edge `id` (`id < num_edges()`).
    fn edge(&self, id: EdgeId) -> Edge;
}

impl EdgeSource for Graph {
    fn num_vertices(&self) -> usize {
        Graph::num_vertices(self)
    }

    fn num_edges(&self) -> usize {
        Graph::num_edges(self)
    }

    #[inline]
    fn edge(&self, id: EdgeId) -> Edge {
        self.edges[id as usize]
    }
}

/// An undirected graph: canonical edge list + CSR adjacency.
///
/// Invariants maintained by [`builder::GraphBuilder`]:
/// * vertex ids are dense `0..num_vertices`
/// * no self loops, no duplicate edges (in either direction)
#[derive(Clone, Debug)]
pub struct Graph {
    edges: EdgeList,
    csr: Csr,
}

impl Graph {
    /// Assemble from parts (used by the builder; not public API).
    pub(crate) fn from_parts(edges: EdgeList, csr: Csr) -> Graph {
        Graph { edges, csr }
    }

    /// Number of vertices `|V|`.
    pub fn num_vertices(&self) -> usize {
        self.csr.num_vertices()
    }

    /// Number of undirected edges `|E|`.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// The canonical edge list.
    pub fn edges(&self) -> &EdgeList {
        &self.edges
    }

    /// Adjacency index.
    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// Degree of `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        self.csr.degree(v)
    }

    /// Neighbour iterator: `(neighbour, edge id)` pairs.
    pub fn neighbors(&self, v: VertexId) -> impl Iterator<Item = (VertexId, crate::EdgeId)> + '_ {
        self.csr.neighbors(v)
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Rebuild this graph with its edge list permuted by `perm`
    /// (`perm[new_position] = old_edge_id`). Used to materialize orderings.
    pub fn permute_edges(&self, perm: &[crate::EdgeId]) -> Graph {
        assert_eq!(perm.len(), self.num_edges(), "permutation length");
        let mut new_edges = Vec::with_capacity(perm.len());
        for &old in perm {
            new_edges.push(self.edges[old as usize]);
        }
        let edges = EdgeList::from_vec(new_edges);
        let csr = Csr::build(self.num_vertices(), &edges);
        Graph { edges, csr }
    }
}

#[cfg(test)]
mod tests {
    use super::builder::GraphBuilder;

    #[test]
    fn permute_edges_preserves_structure() {
        let g = GraphBuilder::new()
            .edge(0, 1)
            .edge(1, 2)
            .edge(2, 0)
            .edge(2, 3)
            .build();
        let perm = vec![3, 2, 1, 0];
        let h = g.permute_edges(&perm);
        assert_eq!(h.num_edges(), 4);
        assert_eq!(h.num_vertices(), 4);
        assert_eq!(h.edges()[0], g.edges()[3]);
        // degrees unchanged
        for v in 0..4 {
            assert_eq!(g.degree(v), h.degree(v));
        }
    }
}
