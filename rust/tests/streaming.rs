//! End-to-end streaming acceptance: an interleaved churn + rescale
//! scenario driven through the coordinator must (a) keep the live
//! replication factor within 10% of a *fresh* GEO+CEP repartition of the
//! mutated graph, and (b) execute every migration/delta plan as O(k)
//! contiguous range operations — no per-edge assignment vector ever
//! exists on the streaming path (the assignment is chunk metadata plus a
//! budget-bounded tombstone list by construction).

use egs::coordinator::{Controller, RunConfig};
use egs::graph::generators::{rmat, RmatParams};
use egs::ordering::geo::GeoConfig;
use egs::runtime::native::NativeBackend;
use egs::scaling::scenario::Scenario;
use egs::stream::{CompactionPolicy, MutationBatch, StagedGraph};

fn geo_cfg() -> GeoConfig {
    GeoConfig { k_min: 2, k_max: 16, delta: None, seed: 11, ..Default::default() }
}

/// The headline acceptance run: churn every 3 iterations, k 6 → 8, the
/// compaction budget tripping along the way.
#[test]
fn interleaved_churn_rescale_keeps_rf_near_fresh_repartition() {
    let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 1);
    let m0 = g.num_edges();
    let scenario = Scenario::interleaved(6, 2, 6, 100, 35);
    let cfg = RunConfig::new()
        .geo(geo_cfg())
        .compaction(CompactionPolicy::with_budget(0.08))
        .seed(7)
        .measure_fresh_baseline(true);
    let out = Controller::drive(g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();

    assert_eq!(out.final_k, 8);
    assert_eq!(out.events.len(), 2);
    assert!(!out.churn_events.is_empty());
    assert!(out.compactions >= 1, "the churn volume must trip the budget");

    // (a) quality: live RF within 10% of a fresh GEO+CEP repartition of
    // the mutated graph (different GEO seed — an independent baseline)
    let fresh = out.fresh_rf.expect("baseline requested");
    assert!(fresh >= 1.0);
    let live = out.final_rf.expect("streaming runs audit the final rf");
    assert!(
        live <= fresh * 1.10,
        "streaming RF {live:.4} drifted beyond 10% of fresh {fresh:.4}"
    );

    // (b) plans: O(k) contiguous range operations, never O(m)
    for ev in &out.events {
        assert!(
            ev.range_moves <= ev.from_k + ev.to_k + 1,
            "rescale {}→{} used {} range moves",
            ev.from_k,
            ev.to_k,
            ev.range_moves
        );
        assert!(ev.range_moves < m0 / 10, "rescale plan scales with m");
        assert!(
            ev.layout_ranges <= ev.to_k,
            "rescale {}→{} left {} ownership intervals",
            ev.from_k,
            ev.to_k,
            ev.layout_ranges
        );
    }
    for cr in &out.churn_events {
        let k_bound = 8 + 8 + 1; // k never exceeds 8 in this scenario
        let bound = k_bound + cr.deleted as usize + (8 + 1);
        assert!(
            cr.range_ops <= bound,
            "churn at iteration {} used {} range ops (bound {bound})",
            cr.at_iteration,
            cr.range_ops
        );
        // the decay budget holds throughout the run
        assert!(
            cr.staging_fraction <= 0.08 + 0.05,
            "staging fraction {} escaped the budget",
            cr.staging_fraction
        );
        // interval-set ownership: staged chunks are contiguous, so the
        // layout never fragments beyond one interval per partition
        assert!(
            cr.layout_ranges <= 8,
            "churn at {} left {} ownership intervals resident",
            cr.at_iteration,
            cr.layout_ranges
        );
    }
    assert!(
        out.layout_ranges <= out.final_k,
        "final layout holds {} ownership intervals for k={}",
        out.layout_ranges,
        out.final_k
    );

    // bookkeeping: live edges track the applied mutations exactly
    let ins: u64 = out.churn_events.iter().map(|c| c.inserted as u64).sum();
    let del: u64 = out.churn_events.iter().map(|c| c.deleted as u64).sum();
    assert_eq!(out.live_edges as u64, m0 as u64 + ins - del);
    assert!(ins > 0 && del > 0, "scenario must actually churn");
}

/// Snapshot round trip: a churned staged graph survives the v2 `.egs`
/// format with physical ids, staging tail and tombstones intact.
#[test]
fn staged_graph_snapshot_round_trips() {
    let g = rmat(&RmatParams { scale: 8, edge_factor: 6, ..Default::default() }, 3);
    let mut sg = StagedGraph::new(g, geo_cfg());
    let mut batch = MutationBatch::new();
    for i in 0..40u32 {
        batch.insert(i % 97, (i * 7 + 13) % 97);
    }
    for id in [2u64, 30, 31, 200] {
        batch.delete(id);
    }
    sg.apply_batch(&batch, 5);

    let mut path = std::env::temp_dir();
    path.push(format!("egs_stream_snap_{}.egs", std::process::id()));
    sg.save(&path).unwrap();
    let mut loaded = StagedGraph::load(&path, geo_cfg()).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.physical_edges(), sg.physical_edges());
    assert_eq!(loaded.live_edges(), sg.live_edges());
    assert_eq!(loaded.staging_len(), sg.staging_len());
    assert_eq!(loaded.tombstones(), sg.tombstones());
    assert_eq!(loaded.num_vertices(), sg.num_vertices());
    use egs::graph::EdgeSource;
    for id in 0..sg.physical_edges() as u64 {
        assert_eq!(loaded.edge(id), sg.edge(id), "edge {id}");
    }
    for v in 0..sg.num_vertices() as u32 {
        assert_eq!(loaded.degree(v), sg.degree(v), "degree of {v}");
    }
    // a loaded snapshot keeps ingesting
    let mut more = MutationBatch::new();
    more.insert(0, 1_000);
    let (outcome, _) = loaded.apply_batch(&more, 5);
    assert_eq!(outcome.inserted, 1);
}
