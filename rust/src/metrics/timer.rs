//! Wall-clock measurement (no `criterion` in the vendored crate set): a
//! small best-practice harness — warm-up runs, N timed repetitions, and
//! median/min/mean/tail reporting so the figure benches are stable.
//!
//! `median` and `min` are exact order statistics over the repetitions;
//! `p90`/`p99` come from the shared [`crate::obs::hist`] log-bucketed
//! histogram, so they carry its ≤ 12.5% bucket-resolution error and
//! match the quantiles the tracing subsystem reports elsewhere.

use std::time::{Duration, Instant};

/// Timing summary over repetitions.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// median wall time (exact order statistic)
    pub median: Duration,
    /// fastest observed run (exact)
    pub min: Duration,
    /// arithmetic mean over the repetitions (exact)
    pub mean: Duration,
    /// 90th-percentile run (log-bucketed, ≤ 12.5% resolution error)
    pub p90: Duration,
    /// 99th-percentile run (log-bucketed, ≤ 12.5% resolution error)
    pub p99: Duration,
    /// repetitions measured
    pub reps: usize,
}

impl Timing {
    /// Median in seconds.
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// Human format (auto units).
    pub fn human(&self) -> String {
        human_duration(self.median)
    }
}

/// Format a duration with sensible units.
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Measure `f` with `warmup` discarded runs and `reps` timed runs.
/// The closure's return value is black-boxed to prevent dead-code elision.
pub fn measure<T, F: FnMut() -> T>(warmup: usize, reps: usize, mut f: F) -> Timing {
    assert!(reps >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    let hist = crate::obs::Histogram::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        let dt = t0.elapsed();
        hist.record(dt.as_nanos() as u64);
        times.push(dt);
    }
    times.sort_unstable();
    let total: Duration = times.iter().sum();
    let snap = hist.snapshot();
    Timing {
        median: times[times.len() / 2],
        min: times[0],
        mean: total / reps as u32,
        p90: Duration::from_nanos(snap.quantile(0.90)),
        p99: Duration::from_nanos(snap.quantile(0.99)),
        reps,
    }
}

/// Time a single run (for long jobs where repetitions are impractical).
pub fn once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_monotonic_work() {
        // black-box the bound so release builds cannot const-fold the loop
        let small = black_box(10_000u64);
        let large = black_box(10_000_000u64);
        let work = |n: u64| (0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(0x9E37));
        let t_small = measure(1, 5, || work(small));
        let t_large = measure(1, 5, || work(large));
        assert!(t_large.median > t_small.median);
        assert!(t_small.min <= t_small.median);
    }

    #[test]
    fn mean_and_tail_quantiles_are_consistent() {
        let n = black_box(100_000u64);
        let t = measure(1, 7, || (0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(0x9E37)));
        // mean lies within the observed range
        assert!(t.mean >= t.min);
        // log-bucketed quantiles are monotone, and the histogram's bucket
        // upper bound is never below the true order statistic
        assert!(t.p99 >= t.p90);
        assert!(t.p90.as_nanos() >= t.median.as_nanos() * 7 / 8);
    }

    #[test]
    fn human_units() {
        assert!(human_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(human_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(human_duration(Duration::from_micros(7)).ends_with(" µs"));
        assert!(human_duration(Duration::from_nanos(9)).ends_with(" ns"));
    }

    #[test]
    fn once_returns_value() {
        let (v, d) = once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
