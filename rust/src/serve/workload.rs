//! Deterministic open-loop point-read workload: Zipf-skewed vertex
//! keys, uniform edge keys, a fixed read-kind rotation, all driven by
//! one seeded [`Rng`] so the same config replays the same reads on any
//! machine at any thread width.

use crate::serve::ServeConfig;
use crate::util::rng::Rng;
use crate::{EdgeId, VertexId};

/// What a point read asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadKind {
    /// a vertex's degree
    Degree,
    /// a vertex's neighborhood (modeled cost scales with degree)
    Neighborhood,
    /// a vertex's application state (e.g. its PageRank score)
    AppState,
    /// an edge id's owning partition (pure metadata read)
    EdgeOwner,
}

/// One generated point read. Vertex-keyed kinds consult `vertex`,
/// [`ReadKind::EdgeOwner`] consults `edge`; both are always populated.
#[derive(Clone, Copy, Debug)]
pub struct ReadOp {
    /// what the read asks for
    pub kind: ReadKind,
    /// the Zipf-sampled vertex key
    pub vertex: VertexId,
    /// the uniformly-sampled edge key
    pub edge: EdgeId,
}

/// Zipf(s) sampler over `0..n` by inverse-CDF lookup. The CDF is
/// precomputed once per key-space size, so sampling is one `f64` draw
/// plus a binary search.
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// Build the CDF over `n` keys with skew exponent `s` (`s = 0` is
    /// uniform). `n` is clamped to at least 1.
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        let n = n.max(1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Number of keys in the sampled space.
    pub fn num_keys(&self) -> usize {
        self.cdf.len()
    }

    /// Draw one key: rank 0 is the hottest.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// The open-loop generator: rotates through the four [`ReadKind`]s,
/// draws vertex keys from [`ZipfSampler`] and edge keys uniformly.
/// Deterministic given ([`ServeConfig::seed`], the key-space sizes it
/// was driven with).
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    rng: Rng,
    zipf: ZipfSampler,
    n_keys: usize,
    issued: u64,
    zipf_s: f64,
}

impl WorkloadGen {
    /// A generator over `n_keys` vertex keys, seeded from `cfg`.
    pub fn new(cfg: &ServeConfig, n_keys: usize) -> WorkloadGen {
        WorkloadGen {
            rng: Rng::new(cfg.seed),
            zipf: ZipfSampler::new(n_keys, cfg.zipf_s),
            n_keys: n_keys.max(1),
            issued: 0,
            zipf_s: cfg.zipf_s,
        }
    }

    /// Track vertex-key-space growth (churn inserts vertices): rebuilds
    /// the Zipf CDF only when the size actually changed. Deterministic
    /// because the key-space size itself is deterministic per iteration.
    pub fn resize_keys(&mut self, n: usize) {
        let n = n.max(1);
        if n != self.n_keys {
            self.zipf = ZipfSampler::new(n, self.zipf_s);
            self.n_keys = n;
        }
    }

    /// Total reads generated so far.
    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Generate the next read. `num_edges` bounds the uniform edge-key
    /// draw (the current *physical* id space, so retired and appended
    /// ids are both reachable mid-plan).
    pub fn next_read(&mut self, num_edges: u64) -> ReadOp {
        let kind = match self.issued % 4 {
            0 => ReadKind::Degree,
            1 => ReadKind::Neighborhood,
            2 => ReadKind::AppState,
            _ => ReadKind::EdgeOwner,
        };
        self.issued += 1;
        let vertex = self.zipf.sample(&mut self.rng) as VertexId;
        let edge = if num_edges == 0 { 0 } else { self.rng.below(num_edges) };
        ReadOp { kind, vertex, edge }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = ZipfSampler::new(1000, 1.1);
        assert_eq!(z.num_keys(), 1000);
        let mut rng = Rng::new(42);
        let mut head = 0u64;
        const DRAWS: u64 = 10_000;
        for _ in 0..DRAWS {
            let key = z.sample(&mut rng);
            assert!(key < 1000);
            if key < 10 {
                head += 1;
            }
        }
        // Zipf(1.1) over 1000 keys puts well over a third of the mass on
        // the top 10 keys; uniform would put ~1% there.
        assert!(head > DRAWS / 4, "head mass {head}/{DRAWS}");
    }

    #[test]
    fn zipf_zero_is_roughly_uniform() {
        let z = ZipfSampler::new(100, 0.0);
        let mut rng = Rng::new(7);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min > 100 && *max < 400, "min {min} max {max}");
    }

    #[test]
    fn generator_is_deterministic_and_cycles_kinds() {
        let cfg = ServeConfig::new().seed(99).zipf_s(1.2);
        let mut a = WorkloadGen::new(&cfg, 500);
        let mut b = WorkloadGen::new(&cfg, 500);
        for i in 0..64 {
            let ra = a.next_read(2_000);
            let rb = b.next_read(2_000);
            assert_eq!(ra.vertex, rb.vertex);
            assert_eq!(ra.edge, rb.edge);
            assert_eq!(ra.kind, rb.kind);
            let expect = match i % 4 {
                0 => ReadKind::Degree,
                1 => ReadKind::Neighborhood,
                2 => ReadKind::AppState,
                _ => ReadKind::EdgeOwner,
            };
            assert_eq!(ra.kind, expect);
            assert!(ra.edge < 2_000);
            assert!((ra.vertex as usize) < 500);
        }
        assert_eq!(a.issued(), 64);
    }

    #[test]
    fn resize_keeps_stream_deterministic_for_same_size_sequence() {
        let cfg = ServeConfig::new();
        let mut a = WorkloadGen::new(&cfg, 100);
        a.resize_keys(100); // no-op: same size must not rebuild or perturb
        let mut b = WorkloadGen::new(&cfg, 100);
        for _ in 0..16 {
            let (ra, rb) = (a.next_read(50), b.next_read(50));
            assert_eq!((ra.vertex, ra.edge), (rb.vertex, rb.edge));
        }
        a.resize_keys(200);
        assert!((0..32).all(|_| (a.next_read(50).vertex as usize) < 200));
    }

    #[test]
    fn degenerate_spaces_do_not_panic() {
        let cfg = ServeConfig::new();
        let mut g = WorkloadGen::new(&cfg, 0);
        let op = g.next_read(0);
        assert_eq!(op.vertex, 0);
        assert_eq!(op.edge, 0);
    }
}
