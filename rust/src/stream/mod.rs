//! **Streaming graph churn** — the paper's §7 future-work item as a
//! subsystem: batched edge mutations over a running, partitioned graph.
//!
//! The static pipeline (GEO → CEP → plans) assumes a frozen edge list.
//! This module makes the list *evolve* while everything downstream keeps
//! working:
//!
//! * [`MutationBatch`] — the ingest unit: edge insertions by endpoint
//!   pair, deletions by physical edge id (tombstones).
//! * [`StagedGraph`] — a GEO-ordered base plus a **locality-aware staging
//!   tail** (insertions are placed through the GEO δ-window machinery so
//!   same-neighborhood edges land contiguously, not appended blind) plus a
//!   tombstone set; physical edge ids stay stable between compactions.
//! * [`StagedAssignment`] — [`crate::partition::PartitionAssignment`]
//!   over `base + staging − tombstones`: O(1) owner queries from chunk
//!   metadata, liveness from the budget-bounded tombstone list — never an
//!   O(m) per-edge vector.
//! * [`ChurnPlan`] — the executable delta of a batch or rescale: retire /
//!   move / append range operations, O(k + batch) of them (tombstoned ids
//!   ride along inside move ranges, so rescales stay ≤ k + k′ + 1 moves),
//!   executed incrementally by [`crate::engine::Engine::apply_churn`].
//! * [`CompactionPolicy`] — when the staging+tombstone quality budget is
//!   spent, [`StagedGraph::compact`] folds everything back through a
//!   fresh GEO pass, amortizing the expensive preprocessing.
//! * [`quality`] — RF / EB / VB of the live state without materializing
//!   anything.
//!
//! The [`crate::coordinator`] drives this end to end: churn batches
//! between application iterations, delta plans into the engine, rescales
//! interleaved with churn, compaction when the budget trips.

pub mod assignment;
pub mod compaction;
pub mod mutation;
pub mod plan;
pub mod quality;
pub mod staged;

pub use assignment::{LiveChunks, StagedAssignment, WeightedStagedAssignment};
pub use compaction::CompactionPolicy;
pub use mutation::{BatchOutcome, EdgeMutation, MutationBatch};
pub use plan::ChurnPlan;
pub use staged::StagedGraph;
