//! Edge mutations and ingest batches.
//!
//! A [`MutationBatch`] is the unit of churn the streaming subsystem
//! ingests: a mixed, ordered sequence of edge **insertions** (by endpoint
//! pair) and **deletions** (by physical edge id — the id space CEP slices,
//! so a deletion is a tombstone over an ordered-list position). Batches are
//! applied atomically by [`crate::stream::StagedGraph::apply_batch`], which
//! reports per-batch accounting through [`BatchOutcome`].

use crate::{EdgeId, VertexId};

/// One edge mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeMutation {
    /// Insert the undirected edge `{u, v}` (new vertex ids are admitted —
    /// the vertex id space grows to cover them).
    Insert {
        /// one endpoint
        u: VertexId,
        /// the other endpoint
        v: VertexId,
    },
    /// Delete the edge with physical id `edge` (tombstoned in place; the
    /// id is reclaimed at the next compaction).
    Delete {
        /// physical edge id in the staged ordering
        edge: EdgeId,
    },
}

/// An ordered batch of edge mutations.
///
/// Mutations are applied in push order, so a batch may delete an existing
/// edge `{u, v}` and then re-insert it. Deletions can only reference edges
/// that existed *before* the batch (ids of same-batch insertions are
/// assigned during ingest and are not yet known to the producer).
#[derive(Clone, Debug, Default)]
pub struct MutationBatch {
    muts: Vec<EdgeMutation>,
    inserts: usize,
    deletes: usize,
}

impl MutationBatch {
    /// Empty batch.
    pub fn new() -> MutationBatch {
        MutationBatch::default()
    }

    /// Queue an insertion of `{u, v}`.
    pub fn insert(&mut self, u: VertexId, v: VertexId) {
        self.muts.push(EdgeMutation::Insert { u, v });
        self.inserts += 1;
    }

    /// Queue a deletion of physical edge id `edge`.
    pub fn delete(&mut self, edge: EdgeId) {
        self.muts.push(EdgeMutation::Delete { edge });
        self.deletes += 1;
    }

    /// Total queued mutations.
    pub fn len(&self) -> usize {
        self.muts.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.muts.is_empty()
    }

    /// Queued insertions.
    pub fn num_inserts(&self) -> usize {
        self.inserts
    }

    /// Queued deletions.
    pub fn num_deletes(&self) -> usize {
        self.deletes
    }

    /// Iterate mutations in application order.
    pub fn iter(&self) -> std::slice::Iter<'_, EdgeMutation> {
        self.muts.iter()
    }
}

impl<'a> IntoIterator for &'a MutationBatch {
    type Item = &'a EdgeMutation;
    type IntoIter = std::slice::Iter<'a, EdgeMutation>;

    fn into_iter(self) -> Self::IntoIter {
        self.muts.iter()
    }
}

/// Per-batch ingest accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchOutcome {
    /// insertions staged (after dedup against the live edge set)
    pub inserted: u32,
    /// insertions skipped: self loops or edges already live
    pub skipped_inserts: u32,
    /// deletions applied (edge was live)
    pub deleted: u32,
    /// deletions skipped: id out of range, already dead, or repeated
    pub skipped_deletes: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_counts_kinds() {
        let mut b = MutationBatch::new();
        b.insert(0, 1);
        b.insert(1, 2);
        b.delete(7);
        assert_eq!(b.len(), 3);
        assert_eq!(b.num_inserts(), 2);
        assert_eq!(b.num_deletes(), 1);
        assert!(!b.is_empty());
        assert_eq!(
            b.iter().next(),
            Some(&EdgeMutation::Insert { u: 0, v: 1 })
        );
    }

    #[test]
    fn empty_batch() {
        let b = MutationBatch::new();
        assert!(b.is_empty());
        assert_eq!(b.iter().count(), 0);
    }
}
