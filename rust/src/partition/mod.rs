//! Graph partitioning algorithms — the full zoo of the paper's Table 4.
//!
//! * Edge partitioners produce an [`EdgePartition`] (a partition id per
//!   edge): CEP, 1D/2D hash, DBH, HDRF, NE, Oblivious, Hybrid-Ginger, BVC.
//! * Vertex partitioners produce a [`VertexPartition`]: METIS-like
//!   multilevel (MTS) and chunk-based vertex partitioning (CVP); they are
//!   compared on edge-partition quality after the §6.2 random
//!   adjacent-vertex conversion ([`vertex2edge`]).

pub mod bvc;
pub mod cep;
pub mod cvp;
pub mod dbh;
pub mod epoch;
pub mod ginger;
pub mod hash1d;
pub mod hash2d;
pub mod hdrf;
pub mod intervals;
pub mod metis_like;
pub mod ne;
pub mod oblivious;
pub mod quality;
pub mod vertex2edge;
pub mod view;
pub mod weighted;

pub use epoch::AssignmentEpoch;
pub use intervals::IdRangeSet;
pub use view::{CepView, PartitionAssignment};
pub use weighted::WeightedCepView;

use crate::graph::Graph;
use crate::PartitionId;

/// An edge partitioning: `assign[edge_id] = partition`.
#[derive(Clone, Debug)]
pub struct EdgePartition {
    /// number of partitions `k`
    pub k: usize,
    /// partition id per edge (indexed by edge id in the graph's edge list)
    pub assign: Vec<PartitionId>,
}

impl EdgePartition {
    /// Construct, asserting all ids are `< k`.
    pub fn new(k: usize, assign: Vec<PartitionId>) -> EdgePartition {
        debug_assert!(assign.iter().all(|&p| (p as usize) < k));
        EdgePartition { k, assign }
    }

    /// Edges per partition.
    pub fn sizes(&self) -> Vec<u64> {
        let mut s = vec![0u64; self.k];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Materialize from a [`cep::Cep`] (chunk metadata → explicit vector).
    pub fn from_cep(c: &cep::Cep) -> EdgePartition {
        let m = c.num_edges();
        let mut assign = Vec::with_capacity(m as usize);
        for p in 0..c.k() as PartitionId {
            let r = c.range(p);
            assign.resize(r.end as usize, p);
        }
        debug_assert_eq!(assign.len(), m as usize);
        EdgePartition { k: c.k(), assign }
    }
}

/// A vertex partitioning: `assign[vertex_id] = partition`.
#[derive(Clone, Debug)]
pub struct VertexPartition {
    /// number of partitions `k`
    pub k: usize,
    /// partition id per vertex
    pub assign: Vec<PartitionId>,
}

impl VertexPartition {
    /// Construct, asserting all ids are `< k`.
    pub fn new(k: usize, assign: Vec<PartitionId>) -> VertexPartition {
        debug_assert!(assign.iter().all(|&p| (p as usize) < k));
        VertexPartition { k, assign }
    }

    /// Vertices per partition.
    pub fn sizes(&self) -> Vec<u64> {
        let mut s = vec![0u64; self.k];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }
}

/// Dispatch an edge partitioner by CLI/bench name. For `"cep"` the graph
/// must already be in the desired edge order (CEP slices the list as-is);
/// pair it with [`crate::ordering::geo`] for the paper's GEO+CEP.
pub fn edge_partition_by_name(
    name: &str,
    g: &Graph,
    k: usize,
    seed: u64,
) -> Option<EdgePartition> {
    Some(match name {
        "cep" => EdgePartition::from_cep(&cep::Cep::new(g.num_edges(), k)),
        "1d" => hash1d::partition(g, k),
        "2d" => hash2d::partition(g, k),
        "dbh" => dbh::partition(g, k),
        "hdrf" => hdrf::partition(g, k, hdrf::LAMBDA_DEFAULT),
        "ne" => ne::partition(g, k, seed),
        "oblivious" => oblivious::partition(g, k),
        "ginger" => ginger::partition(g, k),
        "bvc" => bvc::BvcState::build(g.num_edges(), k, seed).to_partition(),
        "mts" => {
            let vp = metis_like::partition(g, k, seed);
            vertex2edge::convert(g, &vp, seed)
        }
        "cvp" => {
            let vo = crate::ordering::VertexOrdering::identity(g.num_vertices());
            let vp = cvp::partition(&vo, k);
            vertex2edge::convert(g, &vp, seed)
        }
        _ => return None,
    })
}

/// Names accepted by [`edge_partition_by_name`], in the paper's Table 4
/// order.
pub const ALL_EDGE_METHODS: &[&str] =
    &["bvc", "ne", "dbh", "hdrf", "1d", "2d", "mts", "cvp", "cep"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;

    #[test]
    fn all_methods_produce_valid_partitions() {
        let g = erdos_renyi(200, 1000, 1);
        for name in ALL_EDGE_METHODS {
            let p = edge_partition_by_name(name, &g, 7, 42)
                .unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(p.assign.len(), g.num_edges(), "{name}");
            assert_eq!(p.k, 7, "{name}");
            assert!(p.assign.iter().all(|&x| x < 7), "{name}");
            // every edge lands exactly once by construction; sizes sum
            assert_eq!(p.sizes().iter().sum::<u64>(), 1000, "{name}");
        }
    }

    #[test]
    fn from_cep_matches_partition_of() {
        let c = cep::Cep::new(137, 10);
        let ep = EdgePartition::from_cep(&c);
        for i in 0..137u64 {
            assert_eq!(ep.assign[i as usize], c.partition_of(i));
        }
    }
}
