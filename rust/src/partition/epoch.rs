//! **Epoch-versioned ownership snapshots** — the immutable read-side
//! authority the serving tier routes by.
//!
//! Every mutable ownership structure in the pipeline ([`CepView`],
//! [`WeightedCepView`], [`crate::stream::StagedAssignment`], the engine's
//! [`crate::engine::mirrors::PartitionLayout`]) is patched in place while
//! a [`crate::scaling::migration::MigrationPlan`] or
//! [`crate::stream::ChurnPlan`] executes, so nothing could safely answer
//! an owner query mid-splice. An [`AssignmentEpoch`] fixes that by
//! snapshotting everything a reader needs — the assignment view, the
//! per-partition [`IdRangeSet`] layout, the master index, and a strictly
//! monotone epoch id — into one cheap, immutable, `Arc`-shared value:
//!
//! * owner lookup is the same O(1)/O(log k) chunk arithmetic the views
//!   use (never a per-edge vector on the CEP paths),
//! * liveness is an O(log t) probe of the owned, sorted tombstone
//!   snapshot,
//! * publication is an `Arc` pointer swap, so the previous epoch stays
//!   fully readable while the next one is spliced in — the
//!   [`crate::serve`] router double-reads across the pair and no read
//!   ever blocks on a migration.
//!
//! The views are *constructors* of epochs, not long-lived authorities:
//! [`CepView::epoch`], [`WeightedCepView::epoch`] and
//! [`crate::stream::StagedAssignment::epoch`] each freeze their current
//! state into a snapshot and hand ownership of the copy to the epoch.

use super::cep::Cep;
use super::intervals::IdRangeSet;
use super::view::CepView;
use super::weighted::WeightedCepView;
use super::{EdgePartition, PartitionAssignment};
use crate::{EdgeId, PartitionId, VertexId};
use std::ops::Range;
use std::sync::Arc;

/// Sentinel in the master snapshot for vertices without a master
/// (isolated in the layout the snapshot was taken from).
const NO_MASTER: u32 = u32::MAX;

/// The assignment view frozen inside an epoch: chunk metadata for the
/// CEP paths (O(1) owner queries), weighted boundaries after a skew
/// nudge (O(log k)), or a shared materialized vector for the scattered
/// methods.
#[derive(Clone, Debug)]
enum EpochView {
    Chunked(Cep),
    Weighted(WeightedCepView),
    Materialized(Arc<EdgePartition>),
}

/// An immutable, `Arc`-shared snapshot of ownership state at one point
/// in the scale/churn/rebalance chain: assignment view, per-partition
/// [`IdRangeSet`] layout, tombstone set, master index, and the epoch id.
///
/// Epochs are cheap on the chunked paths — O(k) metadata plus the
/// tombstone copy — and never change after construction; the driver
/// publishes a new one on every ownership transition and keeps the
/// previous one readable until the transition's splice has retired.
#[derive(Clone, Debug)]
pub struct AssignmentEpoch {
    id: u64,
    view: EpochView,
    /// sorted, owned tombstone snapshot (empty on batch substrates)
    tombstones: Arc<[EdgeId]>,
    /// master partition per vertex ([`NO_MASTER`] = isolated); empty
    /// when the epoch was built without a layout snapshot
    masters: Arc<[u32]>,
    /// nominal per-partition edge-id intervals, derived from the view
    layout: Arc<[IdRangeSet]>,
}

impl AssignmentEpoch {
    fn build(id: u64, view: EpochView, tombstones: Arc<[EdgeId]>) -> AssignmentEpoch {
        debug_assert!(tombstones.windows(2).all(|w| w[0] < w[1]), "tombstones unsorted");
        let layout: Vec<IdRangeSet> = match &view {
            EpochView::Chunked(c) => {
                (0..c.k() as PartitionId).map(|p| IdRangeSet::from_range(c.range(p))).collect()
            }
            EpochView::Weighted(w) => {
                (0..w.k() as PartitionId).map(|p| IdRangeSet::from_range(w.range(p))).collect()
            }
            EpochView::Materialized(part) => {
                let mut sets = vec![IdRangeSet::new(); part.k];
                for (i, &p) in part.assign.iter().enumerate() {
                    sets[p as usize].push_back(i as EdgeId);
                }
                sets
            }
        };
        AssignmentEpoch {
            id,
            view,
            tombstones,
            masters: Arc::from(Vec::new()),
            layout: Arc::from(layout),
        }
    }

    /// Snapshot a uniform CEP layout — O(k) metadata.
    pub fn from_chunked(id: u64, cep: Cep) -> AssignmentEpoch {
        AssignmentEpoch::build(id, EpochView::Chunked(cep), Arc::from(Vec::new()))
    }

    /// Snapshot skew-nudged weighted boundaries — O(k) metadata.
    pub fn from_weighted(id: u64, view: WeightedCepView) -> AssignmentEpoch {
        AssignmentEpoch::build(id, EpochView::Weighted(view), Arc::from(Vec::new()))
    }

    /// Snapshot a materialized per-edge assignment (scattered methods) —
    /// O(m), shared by `Arc` so republishing the same vector is cheap.
    pub fn from_materialized(id: u64, part: Arc<EdgePartition>) -> AssignmentEpoch {
        AssignmentEpoch::build(id, EpochView::Materialized(part), Arc::from(Vec::new()))
    }

    /// Attach a sorted tombstone snapshot (streaming substrates): the
    /// ids keep their nominal owner but report dead via
    /// [`AssignmentEpoch::is_live`], and [`AssignmentEpoch::owner_of`]
    /// returns `None` for them.
    pub fn with_tombstones(mut self, tombstones: Arc<[EdgeId]>) -> AssignmentEpoch {
        debug_assert!(tombstones.windows(2).all(|w| w[0] < w[1]), "tombstones unsorted");
        self.tombstones = tombstones;
        self
    }

    /// Attach a master-index snapshot (`masters[v]` = master partition of
    /// vertex `v`, `u32::MAX` for isolated vertices) so the epoch can
    /// answer vertex-keyed routing queries.
    pub fn with_masters(mut self, masters: Arc<[u32]>) -> AssignmentEpoch {
        self.masters = masters;
        self
    }

    /// The epoch id — strictly monotone across every ownership
    /// transition (scale, churn, rebalance, compaction) of one run.
    pub fn epoch_id(&self) -> u64 {
        self.id
    }

    /// Owner of edge id `e`: `None` when `e` is beyond the id space or
    /// tombstoned in this epoch, otherwise the O(1)/O(log k) view
    /// lookup.
    #[inline]
    pub fn owner_of(&self, e: EdgeId) -> Option<PartitionId> {
        if e >= self.num_edges() || !self.is_live(e) {
            return None;
        }
        Some(self.nominal_owner(e))
    }

    /// Nominal owner of edge id `e` ignoring liveness — the chunk the id
    /// falls into. Panics (debug) when `e` is beyond the id space.
    #[inline]
    pub fn nominal_owner(&self, e: EdgeId) -> PartitionId {
        match &self.view {
            EpochView::Chunked(c) => c.partition_of(e),
            EpochView::Weighted(w) => w.partition_of(e),
            EpochView::Materialized(p) => p.assign[e as usize],
        }
    }

    /// Master partition of vertex `v`, when a master snapshot was
    /// attached and `v` has one.
    pub fn master_of(&self, v: VertexId) -> Option<PartitionId> {
        match self.masters.get(v as usize) {
            Some(&m) if m != NO_MASTER => Some(m),
            _ => None,
        }
    }

    /// True when a master-index snapshot was attached.
    pub fn has_masters(&self) -> bool {
        !self.masters.is_empty()
    }

    /// Vertices covered by the master snapshot (0 without one).
    pub fn num_vertices(&self) -> usize {
        self.masters.len()
    }

    /// The nominal edge-id intervals of partition `p` in this epoch.
    pub fn owned_ranges(&self, p: PartitionId) -> &[Range<EdgeId>] {
        self.layout.get(p as usize).map(|s| s.ranges()).unwrap_or(&[])
    }

    /// Total intervals across the layout snapshot — the metadata
    /// footprint audit (`layout_ranges`).
    pub fn layout_ranges(&self) -> usize {
        self.layout.iter().map(|s| s.num_ranges()).sum()
    }

    /// Resident bytes of the snapshot's ownership metadata.
    pub fn metadata_bytes(&self) -> usize {
        self.layout.iter().map(|s| s.metadata_bytes()).sum::<usize>()
            + std::mem::size_of_val(&self.tombstones[..])
            + std::mem::size_of_val(&self.masters[..])
    }
}

impl PartitionAssignment for AssignmentEpoch {
    fn k(&self) -> usize {
        match &self.view {
            EpochView::Chunked(c) => c.k(),
            EpochView::Weighted(w) => w.k(),
            EpochView::Materialized(p) => p.k,
        }
    }

    fn num_edges(&self) -> u64 {
        match &self.view {
            EpochView::Chunked(c) => c.num_edges(),
            EpochView::Weighted(w) => w.num_edges(),
            EpochView::Materialized(p) => p.assign.len() as u64,
        }
    }

    #[inline]
    fn partition_of(&self, i: EdgeId) -> PartitionId {
        self.nominal_owner(i)
    }

    #[inline]
    fn is_live(&self, i: EdgeId) -> bool {
        self.tombstones.binary_search(&i).is_err()
    }

    fn num_live_edges(&self) -> u64 {
        self.num_edges() - self.tombstones.len() as u64
    }

    fn as_chunks(&self) -> Option<Vec<Range<EdgeId>>> {
        match &self.view {
            EpochView::Chunked(c) => {
                Some((0..c.k() as PartitionId).map(|p| c.range(p)).collect())
            }
            EpochView::Weighted(w) => {
                Some((0..w.k() as PartitionId).map(|p| w.range(p)).collect())
            }
            EpochView::Materialized(_) => None,
        }
    }
}

impl CepView {
    /// Freeze this view into an [`AssignmentEpoch`] with the given id.
    pub fn epoch(&self, id: u64) -> AssignmentEpoch {
        AssignmentEpoch::from_chunked(id, *self.cep())
    }
}

impl WeightedCepView {
    /// Freeze this view into an [`AssignmentEpoch`] with the given id.
    pub fn epoch(&self, id: u64) -> AssignmentEpoch {
        AssignmentEpoch::from_weighted(id, self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_epoch_matches_cep_arithmetic() {
        let cep = Cep::new(137, 10);
        let ep = CepView::new(cep).epoch(3);
        assert_eq!(ep.epoch_id(), 3);
        assert_eq!(ep.k(), 10);
        assert_eq!(ep.num_edges(), 137);
        assert_eq!(ep.layout_ranges(), 10);
        for i in 0..137u64 {
            assert_eq!(ep.owner_of(i), Some(cep.partition_of(i)));
            assert!(ep.is_live(i));
        }
        assert_eq!(ep.owner_of(137), None);
        for p in 0..10u32 {
            assert_eq!(ep.owned_ranges(p), &[cep.range(p)]);
        }
    }

    #[test]
    fn tombstones_mask_owners_but_not_nominal_owner() {
        let dead: Arc<[EdgeId]> = Arc::from(vec![0u64, 5, 6, 13]);
        let ep = AssignmentEpoch::from_chunked(7, Cep::new(14, 4)).with_tombstones(dead);
        assert_eq!(ep.num_live_edges(), 10);
        assert_eq!(ep.owner_of(5), None);
        assert!(!ep.is_live(5));
        assert_eq!(ep.nominal_owner(5), 1); // paper Fig 3 widths 3,3,4,4
        assert_eq!(ep.owner_of(4), Some(1));
    }

    #[test]
    fn weighted_epoch_uses_boundary_search() {
        let view = WeightedCepView::from_bounds(vec![0, 3, 6, 10, 14]);
        let ep = view.epoch(9);
        assert_eq!(ep.k(), 4);
        for i in 0..14u64 {
            assert_eq!(ep.owner_of(i), Some(view.partition_of(i)));
        }
        assert_eq!(ep.owned_ranges(2), &[6..10]);
    }

    #[test]
    fn materialized_epoch_builds_scattered_layout() {
        let part = Arc::new(EdgePartition::new(2, vec![0, 1, 0, 1, 0]));
        let ep = AssignmentEpoch::from_materialized(1, part);
        assert_eq!(ep.owner_of(0), Some(0));
        assert_eq!(ep.owner_of(3), Some(1));
        assert_eq!(ep.owned_ranges(0), &[0..1, 2..3, 4..5]);
        assert_eq!(ep.layout_ranges(), 5);
        assert!(ep.as_chunks().is_none());
    }

    #[test]
    fn masters_snapshot_answers_vertex_routing() {
        let masters: Arc<[u32]> = Arc::from(vec![0u32, 1, NO_MASTER, 1]);
        let ep = AssignmentEpoch::from_chunked(0, Cep::new(10, 2)).with_masters(masters);
        assert!(ep.has_masters());
        assert_eq!(ep.num_vertices(), 4);
        assert_eq!(ep.master_of(0), Some(0));
        assert_eq!(ep.master_of(2), None); // isolated
        assert_eq!(ep.master_of(99), None); // out of range
    }
}
