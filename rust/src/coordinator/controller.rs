//! Legacy controller surface: the audit records and breakdown rows both
//! run paths report, plus the deprecated `ControllerConfig` /
//! `StreamingConfig` + `run_scenario` / `run_streaming` shims.
//!
//! The run loops themselves live in [`super::driver`] behind the unified
//! [`Controller::drive`] entry point — one loop, one policy hook, one
//! pricing/audit pipeline for both substrates. The shims here translate
//! the legacy config shapes into a [`RunConfig`] (the threshold
//! rebalance folds into [`PolicyConfig::Threshold`]) and convert the
//! unified [`super::driver::RunReport`] back into the legacy breakdown
//! rows, so existing callers keep compiling — and keep their outputs —
//! for one release.

use super::config::{DriveMode, PolicyConfig, RunConfig};
use super::driver::Controller;
use super::provisioner::LatencyModel;
use crate::graph::Graph;
use crate::ordering::geo::GeoConfig;
use crate::par::ThreadConfig;
use crate::runtime::ComputeBackend;
use crate::scaling::netsim::NetModelConfig;
use crate::scaling::network::Network;
use crate::scaling::scenario::Scenario;
use crate::stream::CompactionPolicy;
use crate::Result;

/// When the coordinator nudges chunk boundaries toward the metered
/// per-partition cost profile (CLI: `--rebalance`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RebalanceMode {
    /// never rebalance — boundaries stay the method's own (the default)
    Off,
    /// between supersteps, whenever the metered max/mean cost imbalance
    /// exceeds [`RebalanceConfig::threshold`], re-solve the chunk
    /// boundaries against the metered profile and execute the O(k)
    /// boundary-shift plan
    Threshold,
}

/// Skew-aware rebalancing policy: watches the engine's metered
/// per-partition costs ([`Engine::partition_costs`]) and, past the
/// trigger, nudges the weighted chunk boundaries
/// ([`crate::partition::weighted::balanced_boundaries`]) with a
/// ≤ 2(k−1)-move interval-splice plan. Only chunk-contiguous assignments
/// (the CEP paths) can be nudged; scattered methods ignore the policy.
///
/// This is the config-level surface of
/// [`super::policy::ThresholdPolicy`]: the unified driver runs it as a
/// degenerate scaling policy, and [`PolicyConfig::Threshold`] is the
/// unified way to ask for it.
///
/// [`Engine::partition_costs`]: crate::engine::Engine::partition_costs
#[derive(Clone, Copy, Debug)]
pub struct RebalanceConfig {
    /// the policy
    pub mode: RebalanceMode,
    /// max/mean metered cost imbalance that triggers a boundary nudge in
    /// [`RebalanceMode::Threshold`] (1.0 = perfectly balanced)
    pub threshold: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig { mode: RebalanceMode::Off, threshold: 1.15 }
    }
}

impl RebalanceConfig {
    /// Rebalancing disabled (the default).
    pub fn off() -> RebalanceConfig {
        RebalanceConfig::default()
    }

    /// Threshold policy with the given max/mean trigger.
    pub fn threshold(threshold: f64) -> RebalanceConfig {
        assert!(threshold >= 1.0, "imbalance threshold below 1.0 can never be satisfied");
        RebalanceConfig { mode: RebalanceMode::Threshold, threshold }
    }

    /// Is the threshold policy active?
    pub fn is_threshold(&self) -> bool {
        self.mode == RebalanceMode::Threshold
    }

    /// The equivalent unified policy selection.
    pub fn as_policy(&self) -> PolicyConfig {
        if self.is_threshold() {
            PolicyConfig::Threshold { threshold: self.threshold }
        } else {
            PolicyConfig::Off
        }
    }
}

/// Audit record of one executed boundary rebalance.
#[derive(Clone, Copy, Debug)]
pub struct RebalanceRecord {
    /// iteration whose superstep metering triggered the nudge
    pub at_iteration: u32,
    /// partition count at the time of the nudge
    pub k: usize,
    /// metered max/mean cost imbalance that tripped the threshold
    pub imbalance_before: f64,
    /// solver-modeled imbalance of the installed boundaries (predicted
    /// from the metered per-chunk cost profile, re-measured by the next
    /// superstep)
    pub imbalance_after: f64,
    /// edges the boundary-shift plan migrated
    pub moved_edges: u64,
    /// contiguous range moves executed — ≤ 2(k−1) by construction
    pub range_moves: usize,
    /// ownership intervals resident in the layout after the nudge
    pub layout_ranges: usize,
    /// rebalance network milliseconds the application stalled for
    pub net_blocking_ms: f64,
    /// rebalance network milliseconds hidden behind the app's superstep
    /// window (emulated overlap mode; 0 under the closed form)
    pub net_overlapped_ms: f64,
}

/// Legacy batch-path configuration. Superseded by [`RunConfig`]: the
/// same fields, one builder, plus the policy layer.
#[deprecated(note = "use RunConfig + Controller::drive")]
pub struct ControllerConfig {
    /// partitioning/scaling method: `cep` (graph must be GEO-ordered for
    /// the paper's quality), `1d`, `bvc`, `oblivious`, `ginger`
    pub method: String,
    /// physical network for migration pricing (bandwidth + barrier)
    pub net: Network,
    /// which pricing model runs on `net`: the closed form or the
    /// discrete-event emulator (CLI: `--net-model`), plus the emulator's
    /// skew/overlap knobs
    pub net_model: NetModelConfig,
    /// bytes of application value migrated per edge
    pub value_bytes: u64,
    /// worker provisioning latencies
    pub latency: LatencyModel,
    /// RNG seed for methods that need one
    pub seed: u64,
    /// executor width for engine supersteps (pure execution knob —
    /// results identical at any value; defaults to `PALLAS_THREADS`)
    pub threads: ThreadConfig,
    /// skew-aware boundary rebalancing policy (CLI: `--rebalance`)
    pub rebalance: RebalanceConfig,
}

#[allow(deprecated)]
impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            method: "cep".into(),
            net: Network::gbps(8.0),
            net_model: NetModelConfig::default(),
            value_bytes: 8,
            latency: LatencyModel::default(),
            seed: 42,
            threads: ThreadConfig::default(),
            rebalance: RebalanceConfig::default(),
        }
    }
}

#[allow(deprecated)]
impl From<&ControllerConfig> for RunConfig {
    fn from(c: &ControllerConfig) -> RunConfig {
        RunConfig {
            method: c.method.clone(),
            net: c.net,
            net_model: c.net_model,
            value_bytes: c.value_bytes,
            latency: c.latency,
            seed: c.seed,
            threads: c.threads,
            policy: c.rebalance.as_policy(),
            mode: DriveMode::Batch,
            ..RunConfig::default()
        }
    }
}

/// Audit record of one executed scale event.
#[derive(Clone, Copy, Debug)]
pub struct EventRecord {
    /// partition count before the event
    pub from_k: usize,
    /// partition count after the event
    pub to_k: usize,
    /// edges the plan migrated
    pub migrated_edges: u64,
    /// number of range moves in the executed plan (O(k) for CEP,
    /// up to O(m) for scattered methods)
    pub range_moves: usize,
    /// ownership intervals resident in the layout after the event —
    /// ≤ `to_k` on chunk-contiguous (CEP/streaming) paths, the audit
    /// signal that rescaling stayed pure metadata
    pub layout_ranges: usize,
    /// migration network milliseconds the application stalled for (the
    /// share SCALE accounting charges)
    pub net_blocking_ms: f64,
    /// migration network milliseconds hidden behind the app's superstep
    /// window (emulated overlap mode; 0 under the closed form, which
    /// cannot express overlap)
    pub net_overlapped_ms: f64,
}

/// Table 7 row: total and component times (seconds). `SCALE` combines the
/// measured repartitioning time, the *emulated* migration network time and
/// the provisioning latency; `APP` and `INIT` are measured wall time.
#[derive(Clone, Debug)]
pub struct RunBreakdown {
    /// method name
    pub method: String,
    /// total = init + app + scale + rebalance
    pub all_s: f64,
    /// initialization: initial partitioning + engine build
    pub init_s: f64,
    /// application compute
    pub app_s: f64,
    /// repartition + migration + provisioning
    pub scale_s: f64,
    /// total network seconds the migration traffic was priced at across
    /// all events (blocking + overlapped; only the blocking share is
    /// inside `scale_s`)
    pub net_s: f64,
    /// total migrated edges over all events
    pub migrated_edges: u64,
    /// communication bytes of the app phases
    pub com_bytes: u64,
    /// final partition count
    pub final_k: usize,
    /// ownership intervals resident in the final layout (O(k + moved
    /// ranges), never per-edge)
    pub layout_ranges: usize,
    /// resident bytes of the final layout's ownership metadata
    pub layout_bytes: usize,
    /// skew-aware rebalancing: solver + migration wall plus blocking
    /// network seconds across all boundary nudges (0 when the policy is
    /// [`RebalanceMode::Off`])
    pub rebalance_s: f64,
    /// metered max/mean cost imbalance after the final superstep
    pub final_imbalance: f64,
    /// histogram-backed p50 superstep wall latency across all APP
    /// iterations, in milliseconds (log-bucketed, ≤ 12.5% bucket error;
    /// 0 when the scenario ran no supersteps)
    pub superstep_p50_ms: f64,
    /// histogram-backed p99 superstep wall latency, in milliseconds
    pub superstep_p99_ms: f64,
    /// per-event audit log of the executed plans
    pub events: Vec<EventRecord>,
    /// per-nudge audit log of the rebalance policy
    pub rebalances: Vec<RebalanceRecord>,
}

/// Run PageRank under `scenario`, scaling with `cfg.method`.
/// `backend_for` supplies a compute backend per partition at every epoch.
///
/// Thin shim over [`Controller::drive`] pinned to the batch substrate
/// (churn events in the scenario are ignored, the legacy contract).
/// Clones the graph — `drive` takes it by value.
#[deprecated(note = "use Controller::drive with a RunConfig")]
#[allow(deprecated)]
pub fn run_scenario<F>(
    g: &Graph,
    scenario: &Scenario,
    cfg: &ControllerConfig,
    backend_for: F,
) -> Result<RunBreakdown>
where
    F: FnMut(usize) -> Box<dyn ComputeBackend>,
{
    let run_cfg = RunConfig::from(cfg);
    Ok(Controller::drive(g.clone(), scenario, &run_cfg, backend_for)?.into())
}

// ---------------------------------------------------------------------------
// Streaming: interleaved churn + rescale over a StagedGraph
// ---------------------------------------------------------------------------

/// Legacy streaming-path configuration. Superseded by [`RunConfig`]
/// (with [`DriveMode::Streaming`] or a churn-carrying scenario under
/// [`DriveMode::Auto`]).
#[deprecated(note = "use RunConfig + Controller::drive")]
pub struct StreamingConfig {
    /// physical network for pricing inter-worker rebalancing moves
    pub net: Network,
    /// which pricing model runs on `net` (closed form or emulator, with
    /// the emulator's skew/overlap knobs)
    pub net_model: NetModelConfig,
    /// bytes of application value migrated per edge
    pub value_bytes: u64,
    /// worker provisioning latencies
    pub latency: LatencyModel,
    /// RNG seed for the generated mutation batches
    pub seed: u64,
    /// GEO configuration for the initial ordering and every compaction
    pub geo: GeoConfig,
    /// staging/tombstone quality budget
    pub policy: CompactionPolicy,
    /// fold the staging tail once the scenario ends (a final compaction),
    /// so the run hands steady-state serving a fully GEO-ordered graph
    pub flush_at_end: bool,
    /// record the live replication factor in every [`ChurnRecord`] — an
    /// O(|E|) audit sweep per batch, so off by default (the streaming
    /// path itself stays O(k + batch) per batch); records hold NaN when
    /// disabled
    pub audit_rf: bool,
    /// additionally price a *fresh* GEO+CEP repartition of the final
    /// mutated graph (one extra GEO pass, different seed) and report its
    /// RF — the quality-drift baseline the acceptance criteria compare
    /// against; off by default
    pub measure_fresh_baseline: bool,
    /// executor width for engine supersteps (ingest-side parallelism
    /// follows `geo.threads`); pure execution knob — results identical
    pub threads: ThreadConfig,
    /// skew-aware boundary rebalancing policy (CLI: `--rebalance`); when
    /// active the streaming assignment carries weighted chunk boundaries
    /// over the staged physical id space
    pub rebalance: RebalanceConfig,
}

#[allow(deprecated)]
impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            net: Network::gbps(8.0),
            net_model: NetModelConfig::default(),
            value_bytes: 8,
            latency: LatencyModel::default(),
            seed: 42,
            geo: GeoConfig::default(),
            policy: CompactionPolicy::default(),
            flush_at_end: true,
            audit_rf: false,
            measure_fresh_baseline: false,
            threads: ThreadConfig::default(),
            rebalance: RebalanceConfig::default(),
        }
    }
}

#[allow(deprecated)]
impl From<&StreamingConfig> for RunConfig {
    fn from(c: &StreamingConfig) -> RunConfig {
        RunConfig {
            method: "cep".into(),
            net: c.net,
            net_model: c.net_model,
            value_bytes: c.value_bytes,
            latency: c.latency,
            seed: c.seed,
            threads: c.threads,
            policy: c.rebalance.as_policy(),
            slo_ref_ms: None,
            mode: DriveMode::Streaming,
            geo: c.geo,
            compaction: c.policy,
            flush_at_end: c.flush_at_end,
            audit_rf: c.audit_rf,
            measure_fresh_baseline: c.measure_fresh_baseline,
        }
    }
}

/// Audit record of one executed churn batch.
#[derive(Clone, Copy, Debug)]
pub struct ChurnRecord {
    /// iteration the batch fired before
    pub at_iteration: u32,
    /// insertions staged (after dedup)
    pub inserted: u32,
    /// deletions applied
    pub deleted: u32,
    /// edges retired (tombstoned) by the plan
    pub retired: u64,
    /// edges rebalanced between workers by the plan
    pub moved: u64,
    /// edges appended to workers by the plan
    pub appended: u64,
    /// total range operations actually executed: the delta plan's size,
    /// or `k` full-chunk reloads when the batch tripped a compaction
    pub range_ops: usize,
    /// ownership intervals resident in the layout after the batch — ≤ k
    /// always on the streaming path (staged chunks are contiguous)
    pub layout_ranges: usize,
    /// tombstones outstanding after the batch
    pub tombstones_after: usize,
    /// staging fraction after the batch
    pub staging_fraction: f64,
    /// did this batch trip the compaction budget (full GEO fold + rebuild;
    /// `moved` then counts every live edge and the network time prices the
    /// full redistribution, not the discarded delta plan)
    pub compacted: bool,
    /// rebalancing network milliseconds the application stalled for
    pub net_blocking_ms: f64,
    /// rebalancing network milliseconds hidden behind the app's superstep
    /// window (emulated overlap mode; 0 under the closed form, and 0 for
    /// compactions — a full rebuild cannot overlap)
    pub net_overlapped_ms: f64,
    /// live replication factor after the batch was applied
    /// ([`RunConfig::audit_rf`]; NaN when disabled)
    pub rf: f64,
}

/// Breakdown of a streaming run: Table 7's INIT/APP/SCALE plus a CHURN
/// component, with per-event audit logs.
#[derive(Clone, Debug)]
pub struct StreamingBreakdown {
    /// scenario name
    pub name: String,
    /// total = init + app + scale + churn + rebalance
    pub all_s: f64,
    /// initial GEO ordering + engine build
    pub init_s: f64,
    /// application compute
    pub app_s: f64,
    /// rescale planning + migration + provisioning
    pub scale_s: f64,
    /// churn ingest + delta-plan application + compactions
    pub churn_s: f64,
    /// total network seconds priced across rescales, delta plans and
    /// compaction redistributions (blocking + overlapped)
    pub net_s: f64,
    /// communication bytes of the app phases
    pub com_bytes: u64,
    /// final partition count
    pub final_k: usize,
    /// live replication factor at the end of the run
    pub final_rf: f64,
    /// RF of a fresh GEO+CEP repartition of the final mutated graph
    /// (only when `measure_fresh_baseline` is set)
    pub fresh_rf: Option<f64>,
    /// ownership intervals resident in the final layout
    pub layout_ranges: usize,
    /// resident bytes of the final layout's ownership metadata
    pub layout_bytes: usize,
    /// compactions performed (including a final flush)
    pub compactions: u32,
    /// live edges at the end of the run
    pub live_edges: usize,
    /// skew-aware rebalancing: solver + migration wall plus blocking
    /// network seconds across all boundary nudges (0 when the policy is
    /// [`RebalanceMode::Off`])
    pub rebalance_s: f64,
    /// metered max/mean cost imbalance after the final superstep (before
    /// any end-of-run flush, which rebuilds the engine and clears the
    /// comm lanes)
    pub final_imbalance: f64,
    /// histogram-backed p50 superstep wall latency across all APP
    /// iterations, in milliseconds (log-bucketed, ≤ 12.5% bucket error;
    /// 0 when the scenario ran no supersteps)
    pub superstep_p50_ms: f64,
    /// histogram-backed p99 superstep wall latency, in milliseconds
    pub superstep_p99_ms: f64,
    /// per-rescale audit log
    pub events: Vec<EventRecord>,
    /// per-batch audit log
    pub churn_events: Vec<ChurnRecord>,
    /// per-nudge audit log of the rebalance policy
    pub rebalances: Vec<RebalanceRecord>,
}

/// Run PageRank over an evolving graph: churn batches and rescales fire
/// between iterations per `scenario`, every delta reaches the engine as
/// range operations over a [`crate::stream::StagedAssignment`], and the
/// staged state compacts through GEO when the quality budget is spent.
/// Takes ownership of the graph — the staged base is GEO-ordered once at
/// INIT.
///
/// Thin shim over [`Controller::drive`] pinned to the streaming
/// substrate.
#[deprecated(note = "use Controller::drive with a RunConfig")]
#[allow(deprecated)]
pub fn run_streaming<F>(
    g: Graph,
    scenario: &Scenario,
    cfg: &StreamingConfig,
    backend_for: F,
) -> Result<StreamingBreakdown>
where
    F: FnMut(usize) -> Box<dyn ComputeBackend>,
{
    let run_cfg = RunConfig::from(cfg);
    Ok(Controller::drive(g, scenario, &run_cfg, backend_for)?.into())
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::graph::generators::{rmat, RmatParams};
    use crate::ordering::geo::{self, GeoConfig};
    use crate::runtime::native::NativeBackend;
    use crate::scaling::scenario::Scenario;

    fn small_graph() -> Graph {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 1);
        geo::order(&g, &GeoConfig { k_min: 2, k_max: 8, ..Default::default() }).apply(&g)
    }

    #[test]
    fn cep_scenario_runs_and_accounts() {
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 3); // 3→5 over 9 iters
        let cfg = ControllerConfig::default();
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 5);
        assert_eq!(out.events.len(), 2);
        assert!(out.migrated_edges > 0);
        assert!(out.app_s > 0.0 && out.scale_s > 0.0 && out.init_s > 0.0);
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.rebalance_s)).abs() < 1e-9
        );
        // the default policy is Off: no nudges, no rebalance seconds
        assert!(out.rebalances.is_empty());
        assert_eq!(out.rebalance_s, 0.0);
    }

    /// Acceptance: on the CEP path a coordinator-driven rescale reaches
    /// the engine as O(k) range moves — the executed plans stay bounded by
    /// the chunk-boundary count no matter how many edges the graph has.
    #[test]
    fn cep_rescale_reaches_engine_as_range_moves() {
        let g = small_graph();
        let scenario = Scenario::scale_out(4, 3, 2); // 4→7
        let cfg = ControllerConfig::default();
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 7);
        for ev in &out.events {
            assert!(
                ev.range_moves <= ev.from_k + ev.to_k + 1,
                "{}→{}: {} range moves is not O(k)",
                ev.from_k,
                ev.to_k,
                ev.range_moves
            );
            assert!(ev.migrated_edges > 0);
            // chunk-contiguous target: ownership metadata stays ≤ k
            // intervals after every executed plan
            assert!(
                ev.layout_ranges <= ev.to_k,
                "{}→{}: {} ownership intervals resident",
                ev.from_k,
                ev.to_k,
                ev.layout_ranges
            );
        }
        assert!(out.layout_ranges <= out.final_k);
    }

    #[test]
    fn cep_scales_cheaper_than_stateless_oblivious() {
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 2);
        let mut cep_cfg = ControllerConfig::default();
        cep_cfg.method = "cep".into();
        let mut obl_cfg = ControllerConfig::default();
        obl_cfg.method = "oblivious".into();
        let cep =
            run_scenario(&g, &scenario, &cep_cfg, |_| Box::new(NativeBackend::new())).unwrap();
        let obl =
            run_scenario(&g, &scenario, &obl_cfg, |_| Box::new(NativeBackend::new())).unwrap();
        // CEP's per-event migration obeys Theorem 2 (≈ m/2 per x=1 step)
        let m = g.num_edges() as f64;
        for ev in &cep.events {
            assert!(
                (ev.migrated_edges as f64) < 0.6 * m,
                "CEP event moved {} of {m}",
                ev.migrated_edges
            );
        }
        // both accounted a full breakdown
        assert!(obl.scale_s > 0.0 && cep.scale_s > 0.0);
        assert_eq!(cep.events.len(), obl.events.len());
    }

    #[test]
    fn scale_in_works() {
        let g = small_graph();
        let scenario = Scenario::scale_in(5, 2, 2);
        let cfg = ControllerConfig::default();
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 3);
    }

    #[test]
    fn bvc_and_stateless_methods_still_run() {
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 1, 2);
        for method in ["bvc", "1d", "ginger"] {
            let mut cfg = ControllerConfig::default();
            cfg.method = method.into();
            let out = run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap_or_else(|e| panic!("{method}: {e:#}"));
            assert_eq!(out.final_k, 4, "{method}");
            assert_eq!(out.events.len(), 1, "{method}");
            assert!(out.migrated_edges > 0, "{method}");
        }
    }

    /// Scattered methods through the plan pipeline on **scale-in**: the
    /// diff plan must drain the retired partitions so the engine can
    /// truncate workers (the controller's Preempt path).
    #[test]
    fn scattered_methods_scale_in_through_plans() {
        let g = small_graph();
        let scenario = Scenario::scale_in(5, 2, 2); // 5 → 3
        for method in ["bvc", "1d"] {
            let mut cfg = ControllerConfig::default();
            cfg.method = method.into();
            let out = run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap_or_else(|e| panic!("{method}: {e:#}"));
            assert_eq!(out.final_k, 3, "{method}");
            assert_eq!(out.events.len(), 2, "{method}");
            assert!(out.migrated_edges > 0, "{method}");
        }
    }

    #[test]
    fn streaming_churn_scenario_runs_and_accounts() {
        let g = small_graph();
        let m0 = g.num_edges();
        // churn every 2 iterations, scale 3→5 at iterations 4 and 8
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = StreamingConfig {
            geo: GeoConfig { k_min: 2, k_max: 8, ..Default::default() },
            audit_rf: true,
            ..Default::default()
        };
        let out =
            run_streaming(g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 5);
        assert_eq!(out.events.len(), 2);
        assert_eq!(out.churn_events.len(), scenario.churn.len());
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.churn_s + out.rebalance_s))
                .abs()
                < 1e-9
        );
        assert!(out.app_s > 0.0 && out.churn_s > 0.0 && out.init_s > 0.0);
        // the default policy is Off: no nudges, no rebalance seconds
        assert!(out.rebalances.is_empty());
        assert_eq!(out.rebalance_s, 0.0);
        // the live edge count tracks the applied mutations exactly
        let ins: u64 = out.churn_events.iter().map(|c| c.inserted as u64).sum();
        let del: u64 = out.churn_events.iter().map(|c| c.deleted as u64).sum();
        assert_eq!(out.live_edges as u64, m0 as u64 + ins - del);
        assert!(ins > 0 && del > 0);
        // flush_at_end folded the churn away
        assert!(out.compactions >= 1);
        assert!(out.final_rf >= 1.0);
        for cr in &out.churn_events {
            // delta plans: O(k + batch) range ops, rebalancing moves O(k)
            assert!(
                cr.range_ops <= (5 + 5 + 1) + cr.deleted as usize + (5 + 1),
                "churn at {} used {} range ops",
                cr.at_iteration,
                cr.range_ops
            );
            assert!(cr.staging_fraction <= cfg.policy.budget + 0.05);
            assert!(cr.rf >= 1.0);
            // staged chunks are contiguous: the layout never fragments
            // beyond one interval per partition
            assert!(
                cr.layout_ranges <= 5,
                "churn at {} left {} ownership intervals",
                cr.at_iteration,
                cr.layout_ranges
            );
        }
        for ev in &out.events {
            assert!(
                ev.range_moves <= ev.from_k + ev.to_k + 1,
                "{}→{}: {} range moves is not O(k)",
                ev.from_k,
                ev.to_k,
                ev.range_moves
            );
            assert!(ev.layout_ranges <= ev.to_k);
        }
        assert!(out.layout_ranges <= out.final_k);
    }

    #[test]
    fn streaming_without_churn_matches_plain_scale_shape() {
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 3);
        let cfg = StreamingConfig::default();
        let out =
            run_streaming(g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 5);
        assert!(out.churn_events.is_empty());
        assert_eq!(out.compactions, 0, "no churn, nothing to flush");
        for ev in &out.events {
            assert!(ev.migrated_edges > 0);
            assert!(ev.range_moves <= ev.from_k + ev.to_k + 1);
        }
    }

    /// Acceptance: on single-shuffle CEP plans the emulator (overlap off,
    /// so both models see the same standalone shuffle) agrees with the
    /// closed form well within 1%, and the closed form reports every
    /// priced second as blocking.
    #[test]
    fn emulated_and_closed_form_agree_on_cep_run() {
        use crate::scaling::netsim::{NetModelConfig, NetworkModel};
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 3);
        let closed_cfg = ControllerConfig::default();
        let emu_cfg = ControllerConfig {
            net_model: NetModelConfig {
                model: NetworkModel::Emulated,
                overlap: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let closed =
            run_scenario(&g, &scenario, &closed_cfg, |_| Box::new(NativeBackend::new())).unwrap();
        let emu =
            run_scenario(&g, &scenario, &emu_cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(closed.events.len(), emu.events.len());
        assert!(closed.net_s > 0.0 && emu.net_s > 0.0);
        assert!(
            (closed.net_s - emu.net_s).abs() <= 0.01 * closed.net_s.max(emu.net_s),
            "closed {} vs emulated {}",
            closed.net_s,
            emu.net_s
        );
        for (c, e) in closed.events.iter().zip(&emu.events) {
            assert_eq!(c.net_overlapped_ms, 0.0, "closed form cannot express overlap");
            assert!(c.net_blocking_ms > 0.0);
            let (ct, et) = (c.net_blocking_ms, e.net_blocking_ms + e.net_overlapped_ms);
            assert!((ct - et).abs() <= 0.01 * ct.max(et), "event {ct} vs {et}");
        }
    }

    /// Emulated overlap mode on the `run` path: every event's audit
    /// record splits network time into a blocking and an overlapped
    /// share, and some migration traffic really hides behind the app
    /// window.
    #[test]
    fn emulated_overlap_splits_net_time_on_run() {
        use crate::scaling::netsim::NetModelConfig;
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 3);
        let cfg = ControllerConfig {
            net_model: NetModelConfig::emulated(),
            ..Default::default()
        };
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.events.len(), 2);
        assert!(out.net_s > 0.0);
        for ev in &out.events {
            assert!(ev.net_blocking_ms >= 0.0 && ev.net_overlapped_ms >= 0.0);
            assert!(ev.net_blocking_ms + ev.net_overlapped_ms > 0.0);
            // the modeled compute window is always positive, so a nonzero
            // plan always hides at least some traffic
            assert!(ev.net_overlapped_ms > 0.0, "no overlap on {}→{}", ev.from_k, ev.to_k);
        }
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.rebalance_s)).abs() < 1e-9
        );
    }

    /// Emulated model on the streaming path: churn and rescale records
    /// expose the blocking/overlapped split, and compactions never
    /// overlap (full rebuilds are sync points).
    #[test]
    fn streaming_emulated_model_exposes_net_split() {
        use crate::scaling::netsim::NetModelConfig;
        let g = small_graph();
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = StreamingConfig {
            geo: GeoConfig { k_min: 2, k_max: 8, ..Default::default() },
            net_model: NetModelConfig::emulated(),
            ..Default::default()
        };
        let out =
            run_streaming(g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.churn_s + out.rebalance_s))
                .abs()
                < 1e-9
        );
        assert!(out.net_s > 0.0);
        for ev in &out.events {
            assert!(ev.net_blocking_ms >= 0.0 && ev.net_overlapped_ms >= 0.0);
            assert!(ev.net_blocking_ms + ev.net_overlapped_ms > 0.0, "rescale not priced");
        }
        for cr in &out.churn_events {
            assert!(cr.net_blocking_ms >= 0.0 && cr.net_overlapped_ms >= 0.0);
            if cr.compacted {
                assert_eq!(cr.net_overlapped_ms, 0.0, "a compaction cannot overlap the app");
            }
        }
    }

    /// Threshold rebalancing on the run path: metered skew trips the
    /// policy, every nudge is ≤ 2(k−1) contiguous interval splices that
    /// keep the layout O(k), the solver-modeled imbalance drops, and the
    /// closed form prices every nudge as pure blocking time.
    #[test]
    fn threshold_rebalance_fires_and_reduces_imbalance() {
        use crate::scaling::netsim::NetModelConfig;
        let g = small_graph();
        let scenario = Scenario::steady(4, 6);
        let cfg = ControllerConfig {
            // zero modeled compute: the cost profile is the metered comm
            // lanes alone, which a power-law graph skews hard
            net_model: NetModelConfig { compute_ns_per_edge: 0.0, ..Default::default() },
            rebalance: RebalanceConfig::threshold(1.01),
            ..Default::default()
        };
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 4);
        assert!(out.events.is_empty());
        assert!(!out.rebalances.is_empty(), "comm skew never tripped the 1.01 threshold");
        assert!(out.rebalance_s > 0.0);
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.rebalance_s)).abs() < 1e-9
        );
        for r in &out.rebalances {
            assert!(r.imbalance_before > cfg.rebalance.threshold);
            assert!(
                r.imbalance_after <= r.imbalance_before,
                "nudge at {}: {} -> {}",
                r.at_iteration,
                r.imbalance_before,
                r.imbalance_after
            );
            assert!(r.moved_edges > 0);
            assert!(
                r.range_moves <= 2 * (r.k - 1),
                "nudge at {} used {} moves for k={}",
                r.at_iteration,
                r.range_moves,
                r.k
            );
            assert!(
                r.layout_ranges <= r.k + r.range_moves,
                "nudge at {} left {} ownership intervals",
                r.at_iteration,
                r.layout_ranges
            );
            // closed form: every priced second blocks, none overlaps
            assert!(r.net_blocking_ms > 0.0);
            assert_eq!(r.net_overlapped_ms, 0.0);
        }
        assert!(out.final_imbalance >= 1.0);
        assert!(out.layout_ranges <= out.final_k + 2 * (out.final_k - 1));
    }

    /// Rebalanced (weighted) boundaries survive rescales: the next scale
    /// event plans weighted → uniform in O(k + k') contiguous moves, and
    /// under the emulator every nudge splits into blocking + overlapped
    /// shares like any other migration.
    #[test]
    fn rebalance_composes_with_rescales_under_emulation() {
        use crate::scaling::netsim::NetModelConfig;
        let g = small_graph();
        let scenario = Scenario::scale_out(3, 2, 4); // 3→5 over 12 iters
        let cfg = ControllerConfig {
            // small but positive modeled compute: costs stay comm-driven
            // while the emulator keeps a positive overlap window
            net_model: NetModelConfig { compute_ns_per_edge: 0.1, ..NetModelConfig::emulated() },
            rebalance: RebalanceConfig::threshold(1.01),
            ..Default::default()
        };
        let out =
            run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 5);
        assert_eq!(out.events.len(), 2);
        assert!(!out.rebalances.is_empty(), "comm skew never tripped the 1.01 threshold");
        // rescales from nudged boundaries are still O(k + k') moves
        for ev in &out.events {
            assert!(
                ev.range_moves <= ev.from_k + ev.to_k + 1,
                "{}→{}: {} range moves is not O(k)",
                ev.from_k,
                ev.to_k,
                ev.range_moves
            );
            assert!(ev.layout_ranges <= ev.to_k);
        }
        for r in &out.rebalances {
            assert!(r.range_moves <= 2 * (r.k - 1));
            assert!(r.net_blocking_ms >= 0.0 && r.net_overlapped_ms >= 0.0);
            assert!(r.net_blocking_ms + r.net_overlapped_ms > 0.0, "nudge not priced");
            // fired right after a metered superstep: some traffic hides
            assert!(r.net_overlapped_ms > 0.0, "no overlap at {}", r.at_iteration);
        }
    }

    /// Threshold rebalancing on the streaming path: nudges ride the
    /// weighted staged assignment (tombstones and all), mutation
    /// accounting is untouched, and the breakdown stays consistent.
    #[test]
    fn streaming_threshold_rebalance_nudges_boundaries() {
        use crate::scaling::netsim::NetModelConfig;
        let g = small_graph();
        let m0 = g.num_edges();
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = StreamingConfig {
            geo: GeoConfig { k_min: 2, k_max: 8, ..Default::default() },
            net_model: NetModelConfig { compute_ns_per_edge: 0.0, ..Default::default() },
            rebalance: RebalanceConfig::threshold(1.01),
            audit_rf: true,
            ..Default::default()
        };
        let out =
            run_streaming(g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).unwrap();
        assert_eq!(out.final_k, 5);
        assert!(
            (out.all_s - (out.init_s + out.app_s + out.scale_s + out.churn_s + out.rebalance_s))
                .abs()
                < 1e-9
        );
        assert!(!out.rebalances.is_empty(), "comm skew never tripped the 1.01 threshold");
        assert!(out.rebalance_s > 0.0);
        for r in &out.rebalances {
            assert!(r.imbalance_before > cfg.rebalance.threshold);
            assert!(r.imbalance_after <= r.imbalance_before);
            assert!(r.moved_edges > 0);
            assert!(r.range_moves <= 2 * (r.k - 1));
            assert!(r.layout_ranges <= r.k + r.range_moves);
            assert!(r.net_blocking_ms > 0.0);
        }
        // rebalancing never perturbs the mutation accounting
        let ins: u64 = out.churn_events.iter().map(|c| c.inserted as u64).sum();
        let del: u64 = out.churn_events.iter().map(|c| c.deleted as u64).sum();
        assert_eq!(out.live_edges as u64, m0 as u64 + ins - del);
        for cr in &out.churn_events {
            assert!(cr.rf >= 1.0);
        }
        assert!(out.final_rf >= 1.0);
        assert!(out.final_imbalance >= 1.0);
        assert!(out.layout_ranges <= out.final_k);
    }

    #[test]
    fn unknown_method_errors() {
        let g = small_graph();
        let scenario = Scenario::scale_out(2, 1, 2);
        let mut cfg = ControllerConfig::default();
        cfg.method = "nope".into();
        assert!(run_scenario(&g, &scenario, &cfg, |_| Box::new(NativeBackend::new())).is_err());
    }
}
