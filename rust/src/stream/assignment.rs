//! [`StagedAssignment`] — the streaming counterpart of
//! [`crate::partition::CepView`]: a [`PartitionAssignment`] over
//! `base + staging − tombstones` made of two integers of chunk metadata
//! plus a borrowed (budget-bounded) tombstone list. Every owner query is
//! O(1), liveness is O(log t), per-partition live sizes are O(k log t) —
//! no O(m) per-edge vector exists anywhere on the streaming path.

use crate::partition::cep::Cep;
use crate::partition::{PartitionAssignment, WeightedCepView};
use crate::{EdgeId, PartitionId};
use std::ops::Range;

/// Chunk-based assignment over a staged physical edge-id space.
///
/// Physical ids `0..num_edges()` are sliced by a [`Cep`]; tombstoned ids
/// keep their *nominal* chunk owner (so plans and debug cross-checks can
/// reason about them) but are reported dead via
/// [`PartitionAssignment::is_live`], and every consumer that builds
/// per-partition state skips them. Live balance therefore deviates from
/// CEP's perfect physical balance by at most the tombstone fraction, which
/// the compaction budget bounds.
#[derive(Clone, Copy, Debug)]
pub struct StagedAssignment<'a> {
    cep: Cep,
    tombstones: &'a [EdgeId],
}

impl<'a> StagedAssignment<'a> {
    /// View `cep` with the given sorted tombstone list.
    pub fn new(cep: Cep, tombstones: &'a [EdgeId]) -> StagedAssignment<'a> {
        debug_assert!(tombstones.windows(2).all(|w| w[0] < w[1]), "tombstones unsorted");
        if let Some(&t) = tombstones.last() {
            debug_assert!(t < cep.num_edges(), "tombstone {t} beyond physical id space");
        }
        StagedAssignment { cep, tombstones }
    }

    /// The underlying chunk metadata.
    pub fn cep(&self) -> &Cep {
        &self.cep
    }

    /// The sorted tombstone list.
    pub fn tombstones(&self) -> &[EdgeId] {
        self.tombstones
    }

    /// Physical edge-id range of partition `p` — O(1). May contain dead
    /// ids; pair with [`Self::dead_slice`] to walk only live ids.
    pub fn range(&self, p: PartitionId) -> Range<EdgeId> {
        self.cep.range(p)
    }

    /// The tombstones falling inside `r`, as a sub-slice — O(log t).
    pub fn dead_slice(&self, r: Range<EdgeId>) -> &'a [EdgeId] {
        let a = self.tombstones.partition_point(|&d| d < r.start);
        let b = self.tombstones.partition_point(|&d| d < r.end);
        &self.tombstones[a..b]
    }

    /// Dead ids inside `r` — O(log t).
    pub fn dead_in(&self, r: Range<EdgeId>) -> u64 {
        self.dead_slice(r).len() as u64
    }

    /// Live edges per partition — O(k log t).
    pub fn live_sizes(&self) -> Vec<u64> {
        (0..self.k() as PartitionId)
            .map(|p| self.cep.width(p) - self.dead_in(self.cep.range(p)))
            .collect()
    }

    /// Freeze this assignment into an
    /// [`crate::partition::AssignmentEpoch`] with the given id: the
    /// chunk metadata is copied and the borrowed tombstone list is
    /// snapshotted into owned shared storage, so the epoch outlives the
    /// staged graph state it was taken from.
    pub fn epoch(&self, id: u64) -> crate::partition::AssignmentEpoch {
        crate::partition::AssignmentEpoch::from_chunked(id, self.cep)
            .with_tombstones(std::sync::Arc::from(self.tombstones))
    }
}

impl PartitionAssignment for StagedAssignment<'_> {
    fn k(&self) -> usize {
        self.cep.k()
    }

    fn num_edges(&self) -> u64 {
        self.cep.num_edges()
    }

    #[inline]
    fn partition_of(&self, i: EdgeId) -> PartitionId {
        self.cep.partition_of(i)
    }

    #[inline]
    fn is_live(&self, i: EdgeId) -> bool {
        self.tombstones.binary_search(&i).is_err()
    }

    fn num_live_edges(&self) -> u64 {
        self.cep.num_edges() - self.tombstones.len() as u64
    }

    /// Live sizes — what balance metrics should price for a staged state.
    fn sizes(&self) -> Vec<u64> {
        self.live_sizes()
    }

    /// Physical chunk ranges (holes are dead ids; check
    /// [`PartitionAssignment::is_live`] when walking them).
    fn as_chunks(&self) -> Option<Vec<Range<EdgeId>>> {
        Some((0..self.k() as PartitionId).map(|p| self.cep.range(p)).collect())
    }
}

/// Weighted streaming assignment: a borrowed
/// [`crate::partition::WeightedCepView`] (non-uniform chunk boundaries,
/// the skew-aware rebalance substrate) plus the borrowed tombstone list —
/// the [`StagedAssignment`] shape with the uniform closed forms replaced
/// by the O(log k) boundary search. Tombstoned ids keep their nominal
/// chunk owner and are reported dead via
/// [`PartitionAssignment::is_live`].
#[derive(Clone, Copy, Debug)]
pub struct WeightedStagedAssignment<'a> {
    view: &'a WeightedCepView,
    tombstones: &'a [EdgeId],
}

impl<'a> WeightedStagedAssignment<'a> {
    /// View the weighted boundaries with the given sorted tombstone list.
    pub fn new(
        view: &'a WeightedCepView,
        tombstones: &'a [EdgeId],
    ) -> WeightedStagedAssignment<'a> {
        debug_assert!(tombstones.windows(2).all(|w| w[0] < w[1]), "tombstones unsorted");
        if let Some(&t) = tombstones.last() {
            debug_assert!(t < view.num_edges(), "tombstone {t} beyond physical id space");
        }
        WeightedStagedAssignment { view, tombstones }
    }

    /// The underlying weighted boundary view.
    pub fn view(&self) -> &WeightedCepView {
        self.view
    }

    /// The sorted tombstone list.
    pub fn tombstones(&self) -> &[EdgeId] {
        self.tombstones
    }

    /// The tombstones falling inside `r`, as a sub-slice — O(log t).
    pub fn dead_slice(&self, r: Range<EdgeId>) -> &'a [EdgeId] {
        let a = self.tombstones.partition_point(|&d| d < r.start);
        let b = self.tombstones.partition_point(|&d| d < r.end);
        &self.tombstones[a..b]
    }

    /// Dead ids inside `r` — O(log t).
    pub fn dead_in(&self, r: Range<EdgeId>) -> u64 {
        self.dead_slice(r).len() as u64
    }

    /// Live edges per partition — O(k log t).
    pub fn live_sizes(&self) -> Vec<u64> {
        (0..self.view.k() as PartitionId)
            .map(|p| {
                let r = self.view.range(p);
                (r.end - r.start) - self.dead_in(r)
            })
            .collect()
    }

    /// Freeze this assignment into an
    /// [`crate::partition::AssignmentEpoch`] with the given id (see
    /// [`StagedAssignment::epoch`]).
    pub fn epoch(&self, id: u64) -> crate::partition::AssignmentEpoch {
        crate::partition::AssignmentEpoch::from_weighted(id, self.view.clone())
            .with_tombstones(std::sync::Arc::from(self.tombstones))
    }
}

impl PartitionAssignment for WeightedStagedAssignment<'_> {
    fn k(&self) -> usize {
        self.view.k()
    }

    fn num_edges(&self) -> u64 {
        self.view.num_edges()
    }

    #[inline]
    fn partition_of(&self, i: EdgeId) -> PartitionId {
        self.view.partition_of(i)
    }

    #[inline]
    fn is_live(&self, i: EdgeId) -> bool {
        self.tombstones.binary_search(&i).is_err()
    }

    fn num_live_edges(&self) -> u64 {
        self.view.num_edges() - self.tombstones.len() as u64
    }

    /// Live sizes — what balance metrics should price for a staged state.
    fn sizes(&self) -> Vec<u64> {
        self.live_sizes()
    }

    /// Physical chunk ranges (holes are dead ids; check
    /// [`PartitionAssignment::is_live`] when walking them).
    fn as_chunks(&self) -> Option<Vec<Range<EdgeId>>> {
        Some((0..self.view.k() as PartitionId).map(|p| self.view.range(p)).collect())
    }
}

/// A chunk-contiguous assignment over the staged physical id space that
/// the live quality sweeps ([`crate::stream::quality`]) can walk without
/// materializing per-edge state: an owned physical range per partition,
/// the sorted tombstone sub-slice inside any range, and live per-partition
/// sizes. Implemented by [`StagedAssignment`] (uniform chunks) and
/// [`WeightedStagedAssignment`] (skew-aware boundaries).
pub trait LiveChunks: PartitionAssignment {
    /// Physical edge-id range owned by partition `p` (may contain dead
    /// ids; mask with [`Self::dead_slice_in`]).
    fn owned_range(&self, p: PartitionId) -> Range<EdgeId>;

    /// The tombstones falling inside `r`, as a sorted sub-slice.
    fn dead_slice_in(&self, r: Range<EdgeId>) -> &[EdgeId];

    /// Live edges per partition — O(k log t).
    fn live_counts(&self) -> Vec<u64>;
}

impl LiveChunks for StagedAssignment<'_> {
    fn owned_range(&self, p: PartitionId) -> Range<EdgeId> {
        self.range(p)
    }

    fn dead_slice_in(&self, r: Range<EdgeId>) -> &[EdgeId] {
        self.dead_slice(r)
    }

    fn live_counts(&self) -> Vec<u64> {
        self.live_sizes()
    }
}

impl LiveChunks for WeightedStagedAssignment<'_> {
    fn owned_range(&self, p: PartitionId) -> Range<EdgeId> {
        self.view.range(p)
    }

    fn dead_slice_in(&self, r: Range<EdgeId>) -> &[EdgeId] {
        let a = self.tombstones.partition_point(|&d| d < r.start);
        let b = self.tombstones.partition_point(|&d| d < r.end);
        &self.tombstones[a..b]
    }

    fn live_counts(&self) -> Vec<u64> {
        self.live_sizes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn liveness_and_sizes_respect_tombstones() {
        let dead = vec![0u64, 5, 6, 13];
        let a = StagedAssignment::new(Cep::new(14, 4), &dead);
        // paper Fig 3 widths: 3,3,4,4 — dead: id0 (p0), 5 (p1), 6 (p2), 13 (p3)
        assert_eq!(a.live_sizes(), vec![2, 2, 3, 3]);
        assert_eq!(a.num_live_edges(), 10);
        assert_eq!(a.num_edges(), 14);
        assert!(!a.is_live(5));
        assert!(a.is_live(4));
        assert_eq!(a.dead_slice(3..7), &[5, 6]);
        assert_eq!(a.dead_in(0..14), 4);
    }

    #[test]
    fn no_tombstones_behaves_like_cep_view() {
        let a = StagedAssignment::new(Cep::new(137, 10), &[]);
        let v = crate::partition::CepView::new(Cep::new(137, 10));
        assert_eq!(a.sizes(), v.sizes());
        assert_eq!(a.as_chunks(), v.as_chunks());
        for i in 0..137u64 {
            assert_eq!(a.partition_of(i), v.partition_of(i));
            assert!(a.is_live(i));
        }
    }

    #[test]
    fn weighted_staged_assignment_respects_tombstones() {
        let view = WeightedCepView::from_bounds(vec![0, 3, 6, 10, 14]);
        let dead = vec![0u64, 5, 6, 13];
        let a = WeightedStagedAssignment::new(&view, &dead);
        assert_eq!(a.live_sizes(), vec![2, 2, 3, 3]);
        assert_eq!(a.num_live_edges(), 10);
        assert_eq!(a.num_edges(), 14);
        assert!(!a.is_live(5));
        assert!(a.is_live(4));
        assert_eq!(a.partition_of(6), 2);
        assert_eq!(a.sizes(), a.live_sizes());
        let chunks = a.as_chunks().unwrap();
        assert_eq!(chunks, vec![0..3, 3..6, 6..10, 10..14]);
    }

    #[test]
    fn weighted_on_uniform_grid_matches_staged_assignment() {
        let dead = vec![2u64, 40, 41, 99];
        let cep = Cep::new(137, 10);
        let staged = StagedAssignment::new(cep, &dead);
        let view = WeightedCepView::uniform(cep);
        let weighted = WeightedStagedAssignment::new(&view, &dead);
        assert_eq!(staged.sizes(), weighted.sizes());
        assert_eq!(staged.as_chunks(), weighted.as_chunks());
        assert_eq!(staged.num_live_edges(), weighted.num_live_edges());
        for i in 0..137u64 {
            assert_eq!(staged.partition_of(i), weighted.partition_of(i));
            assert_eq!(staged.is_live(i), weighted.is_live(i));
        }
        // the LiveChunks walk (quality sweeps) agrees too
        assert_eq!(staged.live_counts(), weighted.live_counts());
        for p in 0..10u32 {
            assert_eq!(staged.owned_range(p), weighted.owned_range(p));
            assert_eq!(
                staged.dead_slice_in(staged.owned_range(p)),
                weighted.dead_slice_in(weighted.owned_range(p))
            );
        }
    }
}
