//! Fig 10 — replication factor vs partitioning methods over k = 4..128.
//!
//! Expected shape (paper): NE best, GEO+CEP a close second, both far
//! below the hash family (DBH < 2D < 1D) and BVC; MTS between.

mod common;

use common::BenchLog;
use egs::metrics::table::{f3, Table};
use egs::ordering::geo::{self, GeoConfig};
use egs::partition::quality::replication_factor;
use egs::partition::{edge_partition_by_name, EdgePartition};

const KS: &[usize] = &[4, 8, 16, 32, 64, 128];
const METHODS: &[&str] = &["cep", "ne", "mts", "hdrf", "dbh", "2d", "1d", "bvc", "cvp"];

fn main() {
    let mut log = BenchLog::new("fig10");
    for dataset in ["pokec-s", "road-ca-s", "orkut-s"] {
        let g = common::dataset(dataset);
        let ordered = geo::order(&g, &GeoConfig::default()).apply(&g);
        let mut t = Table::new(
            &format!("Fig 10: RF on {dataset} (|E|={})", g.num_edges()),
            &["method", "k=4", "k=8", "k=16", "k=32", "k=64", "k=128"],
        );
        for &method in METHODS {
            let mut row =
                vec![if method == "cep" { "geo+cep".into() } else { method.to_string() }];
            let mut rf_sum = 0.0;
            let (_, wall) = common::timed_ms(|| {
                for &k in KS {
                    // CEP slices the GEO-ordered list; others see the raw graph
                    let input = if method == "cep" { &ordered } else { &g };
                    let part: EdgePartition =
                        edge_partition_by_name(method, input, k, 42).unwrap();
                    let rf = replication_factor(input, &part);
                    rf_sum += rf;
                    row.push(f3(rf));
                }
            });
            t.row(row);
            log.row(&format!("{method}/{dataset}"), wall, Some(rf_sum / KS.len() as f64));
        }
        t.print();
    }
    log.finish();
    println!("paper Fig 10: NE < GEO+CEP << MTS/HDRF/DBH/2D < 1D < BVC");
}
