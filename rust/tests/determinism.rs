//! Thread-count invariance — the acceptance suite of the deterministic
//! parallel runtime (`egs::par`).
//!
//! Every parallelized path must produce **byte-identical** results for
//! 1, 2 and 8 executor threads: the GEO permutation (parallel GEO at a
//! fixed region count), CSR construction, the RF/EB/VB quality sweeps,
//! engine vertex state across a run + rescale + churn sequence, and
//! staged-batch ingest. CI additionally runs the whole test suite under
//! `PALLAS_THREADS={1,4}`, so any accidental width-dependence anywhere
//! fails twice.

use egs::engine::{Combine, Engine};
use egs::graph::generators::{erdos_renyi, rmat, RmatParams};
use egs::graph::EdgeSource;
use egs::ordering::geo::GeoConfig;
use egs::ordering::geo_parallel;
use egs::par::ThreadConfig;
use egs::partition::quality::vertex_counts_with;
use egs::partition::{cep::Cep, CepView, EdgePartition};
use egs::runtime::native::NativeBackend;
use egs::runtime::StepKind;
use egs::stream::{quality as stream_quality, MutationBatch, StagedGraph};
use egs::util::rng::Rng;

const WIDTHS: [usize; 3] = [1, 2, 8];

fn geo_cfg(threads: usize) -> GeoConfig {
    GeoConfig { k_min: 2, k_max: 8, delta: None, seed: 7, threads: ThreadConfig::new(threads) }
}

/// Parallel GEO: for a fixed region count the permutation depends only on
/// the config, never on the executor width.
#[test]
fn geo_permutation_is_thread_invariant() {
    let g = rmat(&RmatParams { scale: 11, edge_factor: 8, ..Default::default() }, 2);
    let reference = geo_parallel::order(&g, &geo_cfg(1), 4);
    for w in WIDTHS {
        let o = geo_parallel::order(&g, &geo_cfg(w), 4);
        assert_eq!(o.as_slice(), reference.as_slice(), "width {w}");
    }
}

/// CSR construction: adjacency rows (neighbour and edge-id order) are
/// identical at every width.
#[test]
fn csr_is_thread_invariant() {
    use egs::graph::Csr;

    let g = rmat(&RmatParams { scale: 11, edge_factor: 8, ..Default::default() }, 9);
    let n = g.num_vertices();
    let reference = Csr::build_with(n, g.edges(), ThreadConfig::serial());
    for w in WIDTHS {
        let csr = Csr::build_with(n, g.edges(), ThreadConfig::new(w));
        for v in 0..n as u32 {
            assert_eq!(csr.degree(v), reference.degree(v), "width {w} vertex {v}");
            assert!(
                csr.neighbors(v).eq(reference.neighbors(v)),
                "width {w} vertex {v}: adjacency rows diverge"
            );
        }
    }
}

/// RF/EB/VB sweeps: chunked (CEP view), scattered (random vector) and
/// live-staged counts are identical at every width.
#[test]
fn quality_metrics_are_thread_invariant() {
    let g = erdos_renyi(200, 1200, 5);
    let m = g.num_edges();
    let chunked = CepView::new(Cep::new(m, 9));
    let mut rng = Rng::new(0xD3);
    let scattered = EdgePartition::new(6, (0..m).map(|_| rng.below(6) as u32).collect());
    let ref_chunked = vertex_counts_with(&g, &chunked, ThreadConfig::serial());
    let ref_scattered = vertex_counts_with(&g, &scattered, ThreadConfig::serial());
    for w in WIDTHS {
        let t = ThreadConfig::new(w);
        assert_eq!(vertex_counts_with(&g, &chunked, t), ref_chunked, "chunked width {w}");
        assert_eq!(vertex_counts_with(&g, &scattered, t), ref_scattered, "scattered width {w}");
    }

    // live staged counts after churn
    let mut sg = StagedGraph::new(erdos_renyi(150, 700, 8), geo_cfg(1));
    let mut batch = MutationBatch::new();
    let mut rng = Rng::new(0xD4);
    for _ in 0..40 {
        batch.insert(rng.below(150) as u32, rng.below(150) as u32);
    }
    for _ in 0..20 {
        batch.delete(rng.below(700));
    }
    let k = 7;
    sg.apply_batch(&batch, k);
    let assign = sg.assignment(k);
    let reference = stream_quality::live_vertex_counts_with(&sg, &assign, ThreadConfig::serial());
    for w in WIDTHS {
        assert_eq!(
            stream_quality::live_vertex_counts_with(&sg, &assign, ThreadConfig::new(w)),
            reference,
            "live width {w}"
        );
    }
}

/// Staged-batch ingest: physical edge list, tombstones, outcome and plan
/// shape after a batch sequence are identical at every width (the ingest
/// parallelism — dedup lookups, window seeding, tombstone merge — runs at
/// `GeoConfig::threads`).
#[test]
fn staged_ingest_is_thread_invariant() {
    // one flat u64 fingerprint: physical edge list ++ tombstones ++
    // per-batch audit numbers, with sentinels between sections
    let run = |w: usize| -> Vec<u64> {
        let g = erdos_renyi(120, 600, 3);
        let mut sg = StagedGraph::new(g, geo_cfg(w));
        let mut rng = Rng::new(0x516);
        let mut audit: Vec<u64> = Vec::new();
        for round in 0..4 {
            let mut batch = MutationBatch::new();
            for _ in 0..50 {
                let u = rng.below(140) as u32;
                let v = rng.below(140) as u32;
                batch.insert(u, v);
            }
            for _ in 0..15 {
                batch.delete(rng.below(sg.physical_edges() as u64));
            }
            let (out, plan) = sg.apply_batch(&batch, 5);
            audit.extend([
                out.inserted as u64,
                out.deleted as u64,
                plan.moved_edges(),
                plan.range_ops() as u64,
            ]);
            if round == 2 {
                sg.compact();
            }
        }
        let mut print: Vec<u64> = Vec::new();
        for id in 0..sg.physical_edges() as u64 {
            let e = sg.edge(id);
            print.push(((e.u as u64) << 32) | e.v as u64);
        }
        print.push(u64::MAX);
        print.extend_from_slice(sg.tombstones());
        print.push(u64::MAX);
        print.extend(audit);
        print
    };
    let reference = run(1);
    for w in WIDTHS {
        assert_eq!(run(w), reference, "width {w}");
    }
}

/// Emulated network pricing through both controller paths is
/// bit-identical at widths 1/2/8: the emulator consumes only the plan,
/// the config, the layout's modeled compute window and the comm meter's
/// integer lanes — never wall clock, RNG or thread scheduling.
#[test]
fn emulated_net_pricing_is_thread_invariant() {
    use egs::coordinator::{Controller, RunConfig};
    use egs::scaling::netsim::NetModelConfig;
    use egs::scaling::scenario::Scenario;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);

    // batch substrate
    let scenario = Scenario::scale_out(3, 2, 3);
    let run = |w: usize| -> Vec<u64> {
        let mut mc = NetModelConfig::emulated();
        mc.barrier_skew_s = 2e-4;
        let cfg = RunConfig::new().net_model(mc).threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        out.events
            .iter()
            .flat_map(|e| {
                [e.net_blocking_ms.to_bits(), e.net_overlapped_ms.to_bits(), e.migrated_edges]
            })
            .collect()
    };
    let reference = run(1);
    assert!(!reference.is_empty());
    for w in WIDTHS {
        assert_eq!(run(w), reference, "run width {w}: emulated pricing diverges");
    }

    // streaming substrate (churn in the scenario selects it)
    let srun = |w: usize| -> Vec<u64> {
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = RunConfig::new()
            .geo(geo_cfg(w))
            .net_model(NetModelConfig::emulated())
            .threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        out.events
            .iter()
            .flat_map(|e| [e.net_blocking_ms.to_bits(), e.net_overlapped_ms.to_bits()])
            .chain(out.churn_events.iter().flat_map(|c| {
                [c.net_blocking_ms.to_bits(), c.net_overlapped_ms.to_bits(), c.moved]
            }))
            .collect()
    };
    let sreference = srun(1);
    assert!(!sreference.is_empty());
    for w in WIDTHS {
        assert_eq!(srun(w), sreference, "streaming width {w}: emulated pricing diverges");
    }
}

/// Skew-aware rebalancing decisions are bit-identical at widths 1/2/8
/// through both controller paths: the cost meter reads only the
/// deterministic comm-lane tallies and the modeled compute window, the
/// boundary solver is a pure prefix-sum over them, and the priced nudges
/// go through the same width-invariant network models — so every nudge
/// (where it fired, what it measured, what it moved, what it cost) must
/// fingerprint identically no matter the executor width.
#[test]
fn weighted_rebalancing_is_thread_invariant() {
    use egs::coordinator::{Controller, PolicyConfig, RunConfig};
    use egs::scaling::netsim::NetModelConfig;
    use egs::scaling::scenario::Scenario;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);
    let fingerprint = |rs: &[egs::coordinator::RebalanceRecord], final_imb: f64| -> Vec<u64> {
        rs.iter()
            .flat_map(|r| {
                [
                    r.at_iteration as u64,
                    r.k as u64,
                    r.imbalance_before.to_bits(),
                    r.imbalance_after.to_bits(),
                    r.moved_edges,
                    r.range_moves as u64,
                    r.layout_ranges as u64,
                    r.net_blocking_ms.to_bits(),
                    r.net_overlapped_ms.to_bits(),
                ]
            })
            .chain([final_imb.to_bits()])
            .collect()
    };

    // batch substrate: pure comm-lane skew (zero modeled compute) so the
    // threshold policy fires on the power-law graph
    let scenario = Scenario::steady(4, 6);
    let run = |w: usize| -> Vec<u64> {
        let cfg = RunConfig::new()
            .net_model(NetModelConfig { compute_ns_per_edge: 0.0, ..Default::default() })
            .policy(PolicyConfig::Threshold { threshold: 1.01 })
            .threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        fingerprint(&out.rebalances, out.final_imbalance)
    };
    let reference = run(1);
    assert!(reference.len() > 1, "rebalance policy never fired");
    for w in WIDTHS {
        assert_eq!(run(w), reference, "run width {w}: rebalance decisions diverge");
    }

    // streaming substrate: churn + rescale interleaved with the nudges
    let srun = |w: usize| -> Vec<u64> {
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = RunConfig::new()
            .geo(geo_cfg(w))
            .net_model(NetModelConfig { compute_ns_per_edge: 0.0, ..Default::default() })
            .policy(PolicyConfig::Threshold { threshold: 1.01 })
            .threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        fingerprint(&out.rebalances, out.final_imbalance)
    };
    let sreference = srun(1);
    assert!(sreference.len() > 1, "streaming rebalance policy never fired");
    for w in WIDTHS {
        assert_eq!(srun(w), sreference, "streaming width {w}: rebalance decisions diverge");
    }
}

/// Engine vertex state after a run + churn + rescale + run sequence is
/// bit-identical at every width (f32 bit patterns compared), and the
/// interval-set ownership metadata of the layout (per-partition range
/// counts) is identical too — the O(ranges) substrate is as
/// width-invariant as the state it carries.
#[test]
fn engine_state_is_thread_invariant_across_run_rescale_churn() {
    let run = |w: usize| -> (Vec<u32>, u64, f64, Vec<usize>) {
        let t = ThreadConfig::new(w);
        let g = erdos_renyi(180, 900, 11);
        let mut sg = StagedGraph::new(g, geo_cfg(w));
        let mut k = 4usize;
        let mut engine = {
            let assign = sg.assignment(k);
            Engine::new(&sg, &assign, |_| Box::new(NativeBackend::new()))
                .unwrap()
                .with_threads(t)
        };
        let mut n = sg.num_vertices();
        let mut ranks = vec![1.0f32 / n as f32; n];
        let supersteps = |engine: &mut Engine, sg: &StagedGraph, ranks: &mut Vec<f32>| {
            let nn = sg.num_vertices();
            if ranks.len() < nn {
                ranks.resize(nn, 1.0 / nn as f32);
            }
            let aux: Vec<f32> = (0..nn as u32)
                .map(|v| {
                    let d = sg.degree(v);
                    if d == 0 {
                        0.0
                    } else {
                        1.0 / d as f32
                    }
                })
                .collect();
            let active = vec![true; nn];
            for _ in 0..3 {
                let (contrib, _) = engine
                    .superstep(StepKind::PageRank, Combine::Sum, ranks, &aux, &active)
                    .unwrap();
                for v in 0..nn {
                    ranks[v] = 0.15 / nn as f32 + 0.85 * contrib[v];
                }
            }
        };
        supersteps(&mut engine, &sg, &mut ranks);

        // churn batch through the delta-plan path
        let mut rng = Rng::new(0xE5);
        let mut batch = MutationBatch::new();
        for _ in 0..40 {
            batch.insert(rng.below(200) as u32, rng.below(200) as u32);
        }
        for _ in 0..10 {
            batch.delete(rng.below(sg.physical_edges() as u64));
        }
        let (_, plan) = sg.apply_batch(&batch, k);
        {
            let assign = sg.assignment(k);
            engine
                .apply_churn(&sg, &plan, &assign, |_| Box::new(NativeBackend::new()))
                .unwrap();
        }
        n = sg.num_vertices();
        supersteps(&mut engine, &sg, &mut ranks);

        // rescale through the same machinery
        let new_k = 7usize;
        let plan = sg.rescale_plan(k, new_k);
        {
            let assign = sg.assignment(new_k);
            engine
                .apply_churn(&sg, &plan, &assign, |_| Box::new(NativeBackend::new()))
                .unwrap();
        }
        k = new_k;
        supersteps(&mut engine, &sg, &mut ranks);

        engine.comm.reset();
        let aux = vec![0.0f32; n];
        let active = vec![true; n];
        let (out, _) = engine
            .superstep(StepKind::Wcc, Combine::Min, &ranks, &aux, &active)
            .unwrap();
        let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(engine.k(), k);
        let ranges: Vec<usize> = (0..k).map(|p| engine.layout().range_count(p)).collect();
        // chunk-contiguous streaming target: ≤ 1 ownership interval per
        // partition no matter the executor width
        assert!(engine.layout().total_ranges() <= k, "ownership metadata fragmented");
        (bits, engine.comm.total_bytes(), engine.layout().rf(), ranges)
    };
    let (ref_bits, ref_bytes, ref_rf, ref_ranges) = run(1);
    for w in WIDTHS {
        let (bits, bytes, rf, ranges) = run(w);
        assert_eq!(bits, ref_bits, "width {w}: vertex state diverges");
        assert_eq!(bytes, ref_bytes, "width {w}: comm bytes diverge");
        assert!((rf - ref_rf).abs() < 1e-15, "width {w}: layout RF diverges");
        assert_eq!(ranges, ref_ranges, "width {w}: ownership intervals diverge");
    }
}

/// The out-of-core paged substrate is bit-identical to the in-memory
/// staged graph through a full engine chain — run, churn batch,
/// compaction (fresh spill + engine rebuild), rescale — at every cache
/// budget ({1 frame, tiny, effectively unbounded}) and every executor
/// width. The cache only decides *what is resident*; the f32 vertex
/// state, comm-lane tallies and ownership metadata must never see it.
#[test]
fn paged_substrate_is_bit_identical_to_in_memory() {
    use egs::graph::{PagedConfig, PagedEdges};

    fn supersteps(engine: &mut Engine, sg: &StagedGraph, ranks: &mut Vec<f32>) {
        let nn = sg.num_vertices();
        if ranks.len() < nn {
            ranks.resize(nn, 1.0 / nn as f32);
        }
        let aux: Vec<f32> = (0..nn as u32)
            .map(|v| {
                let d = sg.degree(v);
                if d == 0 {
                    0.0
                } else {
                    1.0 / d as f32
                }
            })
            .collect();
        let active = vec![true; nn];
        for _ in 0..3 {
            let (contrib, _) = engine
                .superstep(StepKind::PageRank, Combine::Sum, ranks, &aux, &active)
                .unwrap();
            for v in 0..nn {
                ranks[v] = 0.15 / nn as f32 + 0.85 * contrib[v];
            }
        }
    }

    /// One engine chain; `spill` == `None` runs in memory, otherwise the
    /// engine reads every edge through a paged twin re-spilled after
    /// each mutation of the staged graph (the lockstep-mirror protocol).
    fn chain(
        w: usize,
        spill: Option<&PagedConfig>,
        path: &std::path::Path,
    ) -> (Vec<u32>, u64, Vec<usize>) {
        let t = ThreadConfig::new(w);
        let g = erdos_renyi(180, 900, 11);
        let mut sg = StagedGraph::new(g, geo_cfg(w));
        let k = 4usize;
        let mut twin: Option<PagedEdges> =
            spill.map(|c| sg.spill(path, c.clone()).unwrap());
        let mut engine = {
            let assign = sg.assignment(k);
            match &twin {
                Some(pe) => Engine::new(pe, &assign, |_| Box::new(NativeBackend::new())),
                None => Engine::new(&sg, &assign, |_| Box::new(NativeBackend::new())),
            }
            .unwrap()
            .with_threads(t)
        };
        let mut ranks = vec![1.0f32 / sg.num_vertices() as f32; sg.num_vertices()];
        supersteps(&mut engine, &sg, &mut ranks);

        // churn batch through the delta-plan path
        let mut rng = Rng::new(0xE5);
        let mut batch = MutationBatch::new();
        for _ in 0..40 {
            batch.insert(rng.below(200) as u32, rng.below(200) as u32);
        }
        for _ in 0..10 {
            batch.delete(rng.below(sg.physical_edges() as u64));
        }
        let (_, plan) = sg.apply_batch(&batch, k);
        if let Some(c) = spill {
            twin = Some(sg.spill(path, c.clone()).unwrap());
        }
        {
            let assign = sg.assignment(k);
            match &twin {
                Some(pe) => {
                    engine.apply_churn(pe, &plan, &assign, |_| Box::new(NativeBackend::new()))
                }
                None => {
                    engine.apply_churn(&sg, &plan, &assign, |_| Box::new(NativeBackend::new()))
                }
            }
            .unwrap();
        }
        supersteps(&mut engine, &sg, &mut ranks);

        // compaction renumbers the physical space: fresh spill, fresh
        // engine (the same rebuild the streaming driver performs)
        sg.compact();
        if let Some(c) = spill {
            twin = Some(sg.spill(path, c.clone()).unwrap());
        }
        engine = {
            let assign = sg.assignment(k);
            match &twin {
                Some(pe) => Engine::new(pe, &assign, |_| Box::new(NativeBackend::new())),
                None => Engine::new(&sg, &assign, |_| Box::new(NativeBackend::new())),
            }
            .unwrap()
            .with_threads(t)
        };
        supersteps(&mut engine, &sg, &mut ranks);

        // rescale through the same machinery
        let new_k = 7usize;
        let plan = sg.rescale_plan(k, new_k);
        {
            let assign = sg.assignment(new_k);
            match &twin {
                Some(pe) => {
                    engine.apply_churn(pe, &plan, &assign, |_| Box::new(NativeBackend::new()))
                }
                None => {
                    engine.apply_churn(&sg, &plan, &assign, |_| Box::new(NativeBackend::new()))
                }
            }
            .unwrap();
        }
        supersteps(&mut engine, &sg, &mut ranks);

        engine.comm.reset();
        let n = sg.num_vertices();
        let aux = vec![0.0f32; n];
        let active = vec![true; n];
        let (out, _) =
            engine.superstep(StepKind::Wcc, Combine::Min, &ranks, &aux, &active).unwrap();
        let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        let ranges: Vec<usize> =
            (0..new_k).map(|p| engine.layout().range_count(p)).collect();
        (bits, engine.comm.total_bytes(), ranges)
    }

    let budgets = [
        // one 8-edge frame: every miss evicts
        ("one_frame", PagedConfig { page_bytes: 64, cache_bytes: 64, readahead_pages: 0 }),
        // a few short pages with readahead
        ("tiny", PagedConfig { page_bytes: 256, cache_bytes: 1024, readahead_pages: 2 }),
        // default geometry: effectively unbounded at this scale
        ("unbounded", PagedConfig::default()),
    ];
    let reference = chain(1, None, std::path::Path::new("/dev/null"));
    for (tag, cfg) in &budgets {
        for w in WIDTHS {
            let path = std::env::temp_dir()
                .join(format!("egs_det_paged_{}_{tag}_{w}.egs", std::process::id()));
            let got = chain(w, Some(cfg), &path);
            std::fs::remove_file(&path).ok();
            assert_eq!(got.0, reference.0, "budget {tag} width {w}: vertex state diverges");
            assert_eq!(got.1, reference.1, "budget {tag} width {w}: comm bytes diverge");
            assert_eq!(got.2, reference.2, "budget {tag} width {w}: layout diverges");
        }
    }
}

/// `--spill` is unobservable in every deterministic output of the
/// unified driver: a scale-out run over the paged substrate reports the
/// same events, comm bytes and layout as the resident run at every
/// width — while actually serving edges from disk (cache telemetry
/// present on the report, absent on resident runs).
#[test]
fn driver_spill_run_matches_resident_run() {
    use egs::coordinator::{Controller, RunConfig, RunReport};
    use egs::scaling::scenario::Scenario;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);
    let scenario = Scenario::scale_out(3, 2, 3);
    let fingerprint = |out: &RunReport| -> Vec<u64> {
        out.events
            .iter()
            .flat_map(|e| {
                [
                    e.from_k as u64,
                    e.to_k as u64,
                    e.migrated_edges,
                    e.range_moves as u64,
                    e.layout_ranges as u64,
                ]
            })
            .chain([
                out.com_bytes,
                out.final_k as u64,
                out.layout_ranges as u64,
                out.layout_bytes as u64,
            ])
            .collect()
    };
    let resident = {
        let cfg = RunConfig::new().threads(ThreadConfig::new(2));
        Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
            .unwrap()
    };
    assert!(resident.cache_hit_rate.is_none() && resident.peak_resident_bytes.is_none());
    let dir = std::env::temp_dir().join(format!("egs_det_spill_{}", std::process::id()));
    for w in WIDTHS {
        let cfg =
            RunConfig::new().threads(ThreadConfig::new(w)).spill(&dir).page_cache_mb(1);
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        assert_eq!(fingerprint(&out), fingerprint(&resident), "width {w}");
        let rate = out.cache_hit_rate.expect("spill run must report a hit rate");
        assert!((0.0..=1.0).contains(&rate), "hit rate {rate} out of range");
        assert!(out.peak_resident_bytes.expect("peak resident missing") > 0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// SLO policy decisions are bit-identical at widths 1/2/8 through the
/// unified driver: the sensor snapshot reads only modeled costs and
/// deterministic tallies, candidate pricing goes through width-invariant
/// network models, and hysteresis state advances by iteration — so every
/// `DecisionRecord` (trigger, action, candidate table, predictions,
/// realized patches) must fingerprint identically no matter the width.
#[test]
fn policy_decisions_are_thread_invariant() {
    use egs::coordinator::{Controller, PolicyConfig, RunConfig, ScalingAction, SloConfig};
    use egs::scaling::netsim::NetModelConfig;
    use egs::scaling::scenario::Scenario;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);
    // insert-only burst over a calm window: modeled compute dominates, so
    // the breach (and hence the decision sequence) is load-driven
    let scenario = Scenario::flash_crowd(3, 4, 4, 8, 2_000);

    let run = |w: usize| -> (Vec<u64>, usize) {
        let cfg = RunConfig::new()
            .net_model(NetModelConfig { compute_ns_per_edge: 500.0, ..Default::default() })
            .geo(geo_cfg(w))
            .threads(ThreadConfig::new(w))
            .policy(PolicyConfig::Slo(
                SloConfig::new(1.0).bounds(1, 8).cooldown(1).low_watermark(0.6),
            ));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        let committed = out
            .decisions
            .iter()
            .filter(|d| matches!(d.action, ScalingAction::ScaleTo(_)))
            .count();
        (out.decisions.iter().flat_map(|d| d.fingerprint_words()).collect(), committed)
    };
    let (reference, committed) = run(1);
    assert!(!reference.is_empty(), "policy produced no decision audit");
    assert!(committed > 0, "policy never committed a scale-out");
    for w in WIDTHS {
        assert_eq!(run(w), (reference.clone(), committed), "width {w}: decisions diverge");
    }
}

/// The observability span stream's *logical projection* — ids, nesting,
/// names, tally counters — is bit-identical at widths 1/2/8 through both
/// controller paths. Wall times differ run to run, but
/// [`egs::obs::fingerprint`] excludes them; the span count and FNV
/// fingerprint must therefore match exactly. This is the in-process twin
/// of CI's `trace_check.py`, which re-checks the same property on the
/// `--trace-out` files of the thread matrix.
#[test]
fn trace_fingerprint_is_thread_invariant() {
    use egs::coordinator::{Controller, RunConfig};
    use egs::scaling::netsim::NetModelConfig;
    use egs::scaling::scenario::Scenario;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);

    // batch substrate
    let scenario = Scenario::scale_out(3, 2, 3);
    let run = |w: usize| -> (u64, usize) {
        let cfg =
            RunConfig::new().net_model(NetModelConfig::emulated()).threads(ThreadConfig::new(w));
        let (out, data) = egs::obs::capture(|| {
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
        });
        out.unwrap();
        for name in
            ["scenario", "event:scale", "superstep", "phase:plan-derive", "phase:netsim-price"]
        {
            assert!(
                data.spans.iter().any(|s| s.name == name),
                "width {w}: span {name} missing from the trace"
            );
        }
        (egs::obs::fingerprint(&data.spans), data.spans.len())
    };
    let reference = run(1);
    assert!(reference.1 > 0, "capture produced no spans");
    for w in WIDTHS {
        assert_eq!(run(w), reference, "run width {w}: span stream diverges");
    }

    // streaming substrate (churn in the scenario selects it)
    let srun = |w: usize| -> (u64, usize) {
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = RunConfig::new()
            .geo(geo_cfg(w))
            .net_model(NetModelConfig::emulated())
            .threads(ThreadConfig::new(w));
        let (out, data) = egs::obs::capture(|| {
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
        });
        out.unwrap();
        for name in ["scenario", "event:churn", "event:scale", "phase:ingest", "phase:geo-pass"] {
            assert!(
                data.spans.iter().any(|s| s.name == name),
                "streaming width {w}: span {name} missing from the trace"
            );
        }
        (egs::obs::fingerprint(&data.spans), data.spans.len())
    };
    let sreference = srun(1);
    for w in WIDTHS {
        assert_eq!(srun(w), sreference, "streaming width {w}: span stream diverges");
    }
}

/// The serving read path is bit-identical at widths 1/2/8 on both
/// substrates: the workload generator is seeded, routing reads only
/// epoch metadata, and per-read latency is *modeled* — so every
/// `ServeRecord` (tallies, epoch, p50/p99, the FNV route fingerprint)
/// and the report's aggregate read metrics must never see the executor
/// width.
#[test]
fn serving_read_path_is_thread_invariant() {
    use egs::coordinator::{Controller, RunConfig};
    use egs::scaling::scenario::Scenario;
    use egs::serve::ServeConfig;

    let raw = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() }, 4);
    let g = egs::ordering::geo::order(&raw, &geo_cfg(1)).apply(&raw);
    let serve = ServeConfig::new().read_rate(48).zipf_s(1.1).seed(0xC0FFEE);

    let fingerprint = |out: &egs::coordinator::RunReport| -> Vec<u64> {
        out.serve_events
            .iter()
            .flat_map(|s| {
                [
                    s.at_iteration as u64,
                    s.epoch,
                    s.reads,
                    s.double_reads,
                    s.stale_reads,
                    s.misses,
                    s.errors,
                    s.p50_ms.to_bits(),
                    s.p99_ms.to_bits(),
                    s.route_fp,
                ]
            })
            .chain([
                out.reads,
                out.stale_reads,
                out.read_errors,
                out.read_p50_ms.unwrap().to_bits(),
                out.read_p99_ms.unwrap().to_bits(),
                out.final_epoch,
            ])
            .collect()
    };

    // batch substrate: reads issue across two rescales
    let scenario = Scenario::scale_out(3, 2, 3);
    let run = |w: usize| -> Vec<u64> {
        let cfg = RunConfig::new().serve(serve).threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        assert_eq!(out.read_errors, 0, "width {w}: serving errored");
        fingerprint(&out)
    };
    let reference = run(1);
    assert!(!reference.is_empty());
    for w in WIDTHS {
        assert_eq!(run(w), reference, "width {w}: serving read path diverges");
    }

    // streaming substrate: reads issue across churn batches too
    let srun = |w: usize| -> Vec<u64> {
        let scenario = Scenario::interleaved(3, 2, 4, 60, 20);
        let cfg = RunConfig::new()
            .geo(geo_cfg(w))
            .serve(serve)
            .threads(ThreadConfig::new(w));
        let out =
            Controller::drive(g.clone(), &scenario, &cfg, |_| Box::new(NativeBackend::new()))
                .unwrap();
        assert_eq!(out.read_errors, 0, "streaming width {w}: serving errored");
        fingerprint(&out)
    };
    let sreference = srun(1);
    assert!(!sreference.is_empty());
    for w in WIDTHS {
        assert_eq!(srun(w), sreference, "streaming width {w}: serving read path diverges");
    }
}
