//! **Oblivious** — PowerGraph's greedy streaming heuristic (Gonzalez et
//! al., OSDI'12), the `Oblivious` comparator of Table 6: place each edge
//! using only the endpoint-replica sets accumulated so far.
//!
//! Rules (in order): (1) both endpoints share partitions → least loaded of
//! the intersection; (2) exactly one endpoint is placed → its least-loaded
//! partition; (3) both placed but disjoint → least-loaded partition of the
//! endpoint with more remaining (unseen) edges; (4) neither → globally
//! least loaded.

use super::EdgePartition;
use crate::graph::Graph;
use crate::PartitionId;

/// Streaming greedy/oblivious partitioning.
pub fn partition(g: &Graph, k: usize) -> EdgePartition {
    let n = g.num_vertices();
    let words = k.div_ceil(64);
    let mut replicas = vec![0u64; n * words];
    let bits = |r: &[u64], v: u32| -> Vec<PartitionId> {
        let mut out = Vec::new();
        for w in 0..words {
            let mut word = r[v as usize * words + w];
            while word != 0 {
                let b = word.trailing_zeros() as usize;
                out.push((w * 64 + b) as PartitionId);
                word &= word - 1;
            }
        }
        out
    };
    let set = |r: &mut [u64], v: u32, p: usize| {
        r[v as usize * words + p / 64] |= 1 << (p % 64);
    };
    let mut remaining: Vec<u32> = (0..n as u32).map(|v| g.degree(v) as u32).collect();
    let mut sizes = vec![0u64; k];
    let mut assign = Vec::with_capacity(g.num_edges());

    let least_of = |cands: &[PartitionId], sizes: &[u64]| -> PartitionId {
        *cands.iter().min_by_key(|&&p| (sizes[p as usize], p)).unwrap()
    };

    for e in g.edges().iter() {
        let ru = bits(&replicas, e.u);
        let rv = bits(&replicas, e.v);
        let inter: Vec<PartitionId> = ru.iter().copied().filter(|p| rv.contains(p)).collect();
        let p = if !inter.is_empty() {
            least_of(&inter, &sizes)
        } else if !ru.is_empty() && rv.is_empty() {
            least_of(&ru, &sizes)
        } else if ru.is_empty() && !rv.is_empty() {
            least_of(&rv, &sizes)
        } else if !ru.is_empty() && !rv.is_empty() {
            // disjoint: side with more remaining edges keeps locality
            if remaining[e.u as usize] >= remaining[e.v as usize] {
                least_of(&ru, &sizes)
            } else {
                least_of(&rv, &sizes)
            }
        } else {
            least_of(&(0..k as PartitionId).collect::<Vec<_>>(), &sizes)
        };
        assign.push(p);
        sizes[p as usize] += 1;
        set(&mut replicas, e.u, p as usize);
        set(&mut replicas, e.v, p as usize);
        remaining[e.u as usize] -= 1;
        remaining[e.v as usize] -= 1;
    }
    EdgePartition::new(k, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{rmat, RmatParams};
    use crate::partition::hash1d;
    use crate::partition::quality::replication_factor;

    #[test]
    fn beats_1d_on_powerlaw() {
        let g = rmat(&RmatParams { scale: 11, edge_factor: 12, ..Default::default() }, 5);
        let rf = replication_factor(&g, &partition(&g, 16));
        let rf_1d = replication_factor(&g, &hash1d::partition(&g, 16));
        assert!(rf < rf_1d, "oblivious {rf} vs 1d {rf_1d}");
    }
}
