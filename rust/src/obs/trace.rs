//! Trace sinks: the logical-stream fingerprint and the JSON-lines
//! writer behind `egs elastic --trace-out`.
//!
//! ## Schema (v1)
//!
//! One self-describing JSON object per line; every line carries
//! `"v": 1` and a `"type"`:
//!
//! | type      | fields                                                          |
//! |-----------|-----------------------------------------------------------------|
//! | `meta`    | `tool`, `threads`, `spans`, `fingerprint` (`"0x…"` over spans)  |
//! | `span`    | `id`, `parent` (null for roots), `depth`, `name`, `wall_ns`, `counters` (object) |
//! | `counter` | `name`, `value`                                                 |
//! | `gauge`   | `name`, `value`                                                 |
//! | `hist`    | `name`, `count`, `min`, `max`, `mean`, `p50`, `p90`, `p99`      |
//!
//! Span lines appear in close order (children before parents). The
//! **logical projection** of a span — `(id, parent, depth, name,
//! counters)`, i.e. everything except `wall_ns` — is deterministic at
//! any `PALLAS_THREADS` width; [`fingerprint`] hashes exactly that
//! projection, and `.github/scripts/trace_check.py` re-checks it across
//! the CI thread matrix.

use std::io::Write;
use std::path::Path;

use super::span::{SessionData, SpanRecord};

/// Trace schema version stamped into every emitted line.
pub const TRACE_SCHEMA: u32 = 1;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

#[inline]
fn fnv_bytes(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[inline]
fn fnv_u64(h: u64, v: u64) -> u64 {
    fnv_bytes(h, &v.to_le_bytes())
}

/// FNV-1a hash of the spans' logical projection: `(id, parent, depth,
/// name, counters)` in record order — wall times excluded, so the value
/// is bit-identical across thread widths for a deterministic run.
pub fn fingerprint(spans: &[SpanRecord]) -> u64 {
    let mut h = FNV_OFFSET;
    h = fnv_u64(h, spans.len() as u64);
    for s in spans {
        h = fnv_u64(h, s.id);
        h = fnv_u64(h, s.parent.map_or(0, |p| p + 1));
        h = fnv_u64(h, s.depth as u64);
        h = fnv_u64(h, s.name.len() as u64);
        h = fnv_bytes(h, s.name.as_bytes());
        h = fnv_u64(h, s.counters.len() as u64);
        for (name, v) in &s.counters {
            h = fnv_u64(h, name.len() as u64);
            h = fnv_bytes(h, name.as_bytes());
            h = fnv_u64(h, *v);
        }
    }
    h
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

/// Render a drained session as schema-v1 JSON lines (see module docs).
pub fn render_jsonl(data: &SessionData, threads: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"v\":{TRACE_SCHEMA},\"type\":\"meta\",\"tool\":\"egs\",\"threads\":{threads},\
         \"spans\":{},\"fingerprint\":\"0x{:016x}\"}}\n",
        data.spans.len(),
        fingerprint(&data.spans),
    ));
    for s in &data.spans {
        out.push_str(&format!(
            "{{\"v\":{TRACE_SCHEMA},\"type\":\"span\",\"id\":{},\"parent\":",
            s.id
        ));
        match s.parent {
            Some(p) => out.push_str(&format!("{p}")),
            None => out.push_str("null"),
        }
        out.push_str(&format!(",\"depth\":{},\"name\":\"", s.depth));
        escape_into(&mut out, s.name);
        out.push_str(&format!("\",\"wall_ns\":{},\"counters\":{{", s.wall_ns));
        for (i, (name, v)) in s.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(&mut out, name);
            out.push_str(&format!("\":{v}"));
        }
        out.push_str("}}\n");
    }
    for (name, v) in &data.registry.counters {
        out.push_str(&format!("{{\"v\":{TRACE_SCHEMA},\"type\":\"counter\",\"name\":\""));
        escape_into(&mut out, name);
        out.push_str(&format!("\",\"value\":{v}}}\n"));
    }
    for (name, v) in &data.registry.gauges {
        out.push_str(&format!("{{\"v\":{TRACE_SCHEMA},\"type\":\"gauge\",\"name\":\""));
        escape_into(&mut out, name);
        out.push_str("\",\"value\":");
        push_f64(&mut out, *v);
        out.push_str("}\n");
    }
    for (name, h) in &data.registry.hists {
        out.push_str(&format!("{{\"v\":{TRACE_SCHEMA},\"type\":\"hist\",\"name\":\""));
        escape_into(&mut out, name);
        out.push_str(&format!(
            "\",\"count\":{},\"min\":{},\"max\":{},\"mean\":",
            h.count,
            if h.is_empty() { 0 } else { h.min },
            h.max,
        ));
        push_f64(&mut out, h.mean());
        out.push_str(&format!(
            ",\"p50\":{},\"p90\":{},\"p99\":{}}}\n",
            h.quantile(0.50),
            h.quantile(0.90),
            h.quantile(0.99),
        ));
    }
    out
}

/// Write [`render_jsonl`] output to `path`.
pub fn write_jsonl(path: &Path, data: &SessionData, threads: usize) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_jsonl(data, threads).as_bytes())?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::super::span::capture;
    use super::super::{counter_add, gauge_set, hist_record, span};
    use super::*;
    use crate::util::json::Json;

    fn sample() -> SessionData {
        let ((), data) = capture(|| {
            let root = span("scenario");
            root.add("iterations", 4);
            {
                let ss = span("superstep");
                ss.add("partitions", 3);
                let ph = span("phase:scatter");
                ph.add("messages", 12);
                ph.add("bytes", 96);
            }
            counter_add("splices", 5);
            gauge_set("imbalance", 1.25);
            hist_record("superstep_wall_ns", 1000);
            hist_record("superstep_wall_ns", 2000);
        });
        data
    }

    #[test]
    fn fingerprint_ignores_wall_time_only() {
        let mut a = sample().spans;
        let mut b = a.clone();
        for s in &mut b {
            s.wall_ns = s.wall_ns.wrapping_add(12345);
        }
        assert_eq!(fingerprint(&a), fingerprint(&b));
        // ...but any logical change moves it
        b[0].counters[0].1 += 1;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        a[0].depth += 1;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&[]));
    }

    #[test]
    fn rendered_lines_parse_as_json() {
        let data = sample();
        let text = render_jsonl(&data, 4);
        let lines: Vec<&str> = text.lines().collect();
        // meta + 3 spans + 1 counter + 1 gauge + 1 hist
        assert_eq!(lines.len(), 7);
        for line in &lines {
            let j = Json::parse(line).expect("line parses");
            assert_eq!(j.get("v").and_then(Json::as_usize), Some(1));
            assert!(j.get("type").and_then(Json::as_str).is_some(), "{line}");
        }
        let meta = Json::parse(lines[0]).unwrap();
        assert_eq!(meta.get("type").and_then(Json::as_str), Some("meta"));
        assert_eq!(meta.get("threads").and_then(Json::as_usize), Some(4));
        assert_eq!(meta.get("spans").and_then(Json::as_usize), Some(3));
        let fp = meta.get("fingerprint").and_then(Json::as_str).unwrap();
        assert_eq!(fp, format!("0x{:016x}", fingerprint(&data.spans)));
        // spans are in close order: phase before superstep before scenario
        let first = Json::parse(lines[1]).unwrap();
        assert_eq!(first.get("name").and_then(Json::as_str), Some("phase:scatter"));
        assert_eq!(first.get("depth").and_then(Json::as_usize), Some(2));
        assert_eq!(
            first.get("counters").and_then(|c| c.get("messages")).and_then(Json::as_usize),
            Some(12)
        );
        let hist = Json::parse(lines[6]).unwrap();
        assert_eq!(hist.get("type").and_then(Json::as_str), Some("hist"));
        assert_eq!(hist.get("count").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn strings_are_escaped() {
        let mut s = String::new();
        escape_into(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "a\\\"b\\\\c\\nd");
    }
}
