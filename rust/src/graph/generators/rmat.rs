//! R-MAT recursive matrix generator (Chakrabarti, Zhan, Faloutsos, SDM'04).
//!
//! The paper's own scalability study (Fig 15) uses RMAT with edge factors
//! 16–40; we use the same generator both for that experiment and as the
//! stand-in for the skewed social graphs of Table 3.

use crate::graph::builder::GraphBuilder;
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::VertexId;

/// RMAT quadrant probabilities. Defaults are the widely used
/// (a,b,c,d) = (0.57, 0.19, 0.19, 0.05) "social network" setting.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    /// top-left quadrant probability
    pub a: f64,
    /// top-right
    pub b: f64,
    /// bottom-left
    pub c: f64,
    /// log2 of the vertex id space
    pub scale: u32,
    /// average undirected degree (edge factor); |E| ≈ ef · 2^scale
    pub edge_factor: usize,
    /// probability noise added per level to break exact self-similarity
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, scale: 14, edge_factor: 16, noise: 0.05 }
    }
}

/// Generate an RMAT graph. Vertex ids are compacted to `0..|V(E)|` so the
/// returned graph has no isolated vertices (matching how SNAP datasets are
/// consumed after relabelling). Deduplication means the realized edge count
/// is slightly below `edge_factor << scale`.
pub fn rmat(p: &RmatParams, seed: u64) -> Graph {
    let n: u64 = 1u64 << p.scale;
    let target_edges = p.edge_factor as u64 * n;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new();
    for _ in 0..target_edges {
        let (u, v) = sample_edge(p, n, &mut rng);
        b.push(u, v);
    }
    b.build_compacted()
}

fn sample_edge(p: &RmatParams, n: u64, rng: &mut Rng) -> (VertexId, VertexId) {
    let mut lo_u = 0u64;
    let mut lo_v = 0u64;
    let mut span = n;
    while span > 1 {
        // per-level jitter keeps the degree distribution power-law-ish
        // without the artificial striping of exact RMAT
        let ja = p.a * (1.0 + p.noise * (rng.f64() - 0.5));
        let jb = p.b * (1.0 + p.noise * (rng.f64() - 0.5));
        let jc = p.c * (1.0 + p.noise * (rng.f64() - 0.5));
        let total = ja + jb + jc + (1.0 - p.a - p.b - p.c);
        let r = rng.f64() * total;
        span /= 2;
        if r < ja {
            // top-left: nothing to add
        } else if r < ja + jb {
            lo_v += span;
        } else if r < ja + jb + jc {
            lo_u += span;
        } else {
            lo_u += span;
            lo_v += span;
        }
    }
    (lo_u as VertexId, lo_v as VertexId)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_roughly_target_size() {
        let p = RmatParams { scale: 10, edge_factor: 8, ..Default::default() };
        let g = rmat(&p, 1);
        // dedup + self-loop removal shrink the edge set; expect >60%
        assert!(g.num_edges() > 8 * 1024 * 6 / 10, "edges={}", g.num_edges());
        assert!(g.num_vertices() <= 1024);
        assert!(g.num_vertices() > 256);
    }

    #[test]
    fn skewed_degree_distribution() {
        let p = RmatParams { scale: 12, edge_factor: 8, ..Default::default() };
        let g = rmat(&p, 2);
        let max_d = g.max_degree();
        let avg_d = 2.0 * g.num_edges() as f64 / g.num_vertices() as f64;
        // hubs should be far above the mean in a skewed graph
        assert!(max_d as f64 > 8.0 * avg_d, "max={max_d} avg={avg_d}");
    }

    #[test]
    fn deterministic_per_seed() {
        let p = RmatParams { scale: 8, edge_factor: 4, ..Default::default() };
        let g1 = rmat(&p, 5);
        let g2 = rmat(&p, 5);
        assert_eq!(g1.num_edges(), g2.num_edges());
        assert_eq!(g1.edges().as_slice(), g2.edges().as_slice());
        let g3 = rmat(&p, 6);
        assert_ne!(g1.edges().as_slice(), g3.edges().as_slice());
    }
}
