"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps the shape space (vertex counts, edge counts around the
block boundary, mask densities); assert_allclose everywhere.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import edge_ops, ref
from tests.conftest import make_inputs


def _inputs(seed, nv, ne, pad_frac):
    rng = np.random.default_rng(seed)
    return make_inputs(rng, nv, ne, pad_frac)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nv=st.sampled_from([3, 17, 64, 300, 1024]),
    blocks=st.sampled_from([1, 2, 3]),
    pad=st.sampled_from([0.0, 0.3, 0.95]),
)
def test_pr_messages_match_ref(seed, nv, blocks, pad):
    ne = edge_ops.EDGE_BLOCK * blocks
    state, aux, src, dst, weight, mask = _inputs(seed, nv, ne, pad)
    got = edge_ops.pr_messages(state, aux, src, mask)
    want = ref.pr_messages_ref(state, aux, src, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nv=st.sampled_from([5, 33, 257, 1024]),
    blocks=st.sampled_from([1, 2]),
    pad=st.sampled_from([0.0, 0.5]),
)
def test_sssp_messages_match_ref(seed, nv, blocks, pad):
    ne = edge_ops.EDGE_BLOCK * blocks
    state, aux, src, dst, weight, mask = _inputs(seed, nv, ne, pad)
    got = edge_ops.sssp_messages(state, aux, src, weight, mask)
    want = ref.sssp_messages_ref(state, aux, src, weight, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nv=st.sampled_from([4, 100, 2048]),
    blocks=st.sampled_from([1, 2]),
    pad=st.sampled_from([0.0, 0.4]),
)
def test_wcc_messages_match_ref(seed, nv, blocks, pad):
    ne = edge_ops.EDGE_BLOCK * blocks
    state, aux, src, dst, weight, mask = _inputs(seed, nv, ne, pad)
    got = edge_ops.wcc_messages(state, aux, src, mask)
    want = ref.wcc_messages_ref(state, aux, src, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sub_block_edge_count_works():
    # fewer edges than one block: grid collapses to a single block
    state, aux, src, dst, weight, mask = _inputs(7, 50, 640, 0.1)
    got = edge_ops.pr_messages(state, aux, src, mask)
    want = ref.pr_messages_ref(state, aux, src, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_unaligned_edge_count_rejected():
    state, aux, src, dst, weight, mask = _inputs(7, 50, edge_ops.EDGE_BLOCK + 7, 0.1)
    with pytest.raises(AssertionError, match="padded"):
        edge_ops.pr_messages(state, aux, src, mask)


def test_fully_masked_block_is_neutral():
    state, aux, src, dst, weight, mask = _inputs(9, 20, edge_ops.EDGE_BLOCK, 0.0)
    mask[:] = 0.0
    np.testing.assert_array_equal(
        np.asarray(edge_ops.pr_messages(state, aux, src, mask)), 0.0
    )
    assert float(np.min(edge_ops.sssp_messages(state, aux, src, weight, mask))) == float(
        np.float32(edge_ops.MASKED)
    )
