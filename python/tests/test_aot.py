"""AOT pipeline sanity: lowering produces parseable HLO text with the
expected parameter arity, and the manifest round-trips."""

import json
import os

from compile import aot, model


def test_lower_smallest_variant_has_six_params():
    text = aot.lower_app("pagerank", 64, 2048)
    assert "ENTRY" in text
    # six parameters in the ENTRY computation (sub-computations have their
    # own parameter(i) lines, so scope to the ENTRY block)
    entry = text[text.index("ENTRY") :]
    for i in range(6):
        assert f"parameter({i})" in entry, f"missing parameter({i}) in ENTRY"
    assert "f32[64]" in entry
    assert "s32[2048]" in entry


def test_min_apps_lower():
    for app in model.APPS:
        text = aot.lower_app(app, 32, 2048)
        assert "ENTRY" in text, app
        # min-combine apps must contain a scatter or select chain
        assert len(text) > 500, app


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, variants=[(32, 2048)], apps=["wcc"])
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["version"] == 1
    v = on_disk["variants"][0]
    assert v["vcap"] == 32 and v["ecap"] == 2048
    hlo_path = os.path.join(out, v["files"]["wcc"])
    assert os.path.exists(hlo_path)
    assert "ENTRY" in open(hlo_path).read()
