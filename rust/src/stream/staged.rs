//! [`StagedGraph`] — the evolving-graph substrate: a GEO-ordered base edge
//! list, a locality-aware staging tail for insertions, and a tombstone set
//! for deletions.
//!
//! Physical edge ids are positions in `base ++ staging`; they are stable
//! between compactions, so CEP chunk arithmetic, churn plans and the
//! engine's per-partition edge-id sets all speak the same id language.
//! Deletions tombstone an id in place (the hole is reclaimed at the next
//! compaction); insertions are appended to the staging tail in an order
//! chosen by the GEO δ-window machinery so that same-neighborhood edges
//! land contiguously instead of interleaving at random.

use super::assignment::{StagedAssignment, WeightedStagedAssignment};
use super::compaction::CompactionPolicy;
use super::mutation::{BatchOutcome, EdgeMutation, MutationBatch};
use super::plan::{merge_sorted_par, ChurnPlan};
use crate::graph::{io, Csr, Edge, EdgeList, EdgeSource, Graph, PagedConfig, PagedEdges};
use crate::ordering::geo::{self, GeoConfig};
use crate::ordering::window::TailWindow;
use crate::par;
use crate::partition::cep::Cep;
use crate::{EdgeId, Result, VertexId};
use std::collections::{HashMap, HashSet};
use std::path::Path;

/// An ordered edge list under streaming insertions and deletions.
pub struct StagedGraph {
    /// GEO-ordered base (physical ids `0..base.num_edges()`)
    base: Graph,
    /// staged insertions since the last compaction (physical ids
    /// `base.num_edges()..physical_edges()`)
    staging: Vec<Edge>,
    /// sorted physical ids of deleted edges (base or staged)
    tombstones: Vec<EdgeId>,
    /// vertex id space (monotone — never shrinks while the engine runs)
    n: usize,
    /// live degree per vertex
    deg: Vec<u32>,
    /// canonical endpoint pair → physical id, live staged edges only
    staged_index: HashMap<(VertexId, VertexId), EdgeId>,
    cfg: GeoConfig,
    policy: CompactionPolicy,
    compactions: u32,
    /// permutation of the most recent GEO pass (`perm[new] = old id` in
    /// the edge list that pass consumed) — persisted by snapshots
    last_perm: Vec<EdgeId>,
}

impl StagedGraph {
    /// Take ownership of a graph and GEO-order it once as the base.
    pub fn new(g: Graph, cfg: GeoConfig) -> StagedGraph {
        let sp = crate::obs::span("phase:geo-pass");
        sp.add("edges", g.num_edges() as u64);
        sp.add("vertices", g.num_vertices() as u64);
        let perm = geo::order(&g, &cfg).into_perm();
        let base = g.permute_edges(&perm);
        drop(g);
        drop(sp);
        let n = base.num_vertices();
        let deg = (0..n as VertexId).map(|v| base.degree(v) as u32).collect();
        StagedGraph {
            base,
            staging: Vec::new(),
            tombstones: Vec::new(),
            n,
            deg,
            staged_index: HashMap::new(),
            cfg,
            policy: CompactionPolicy::default(),
            compactions: 0,
            last_perm: perm,
        }
    }

    /// Replace the compaction policy (builder style).
    pub fn with_policy(mut self, policy: CompactionPolicy) -> StagedGraph {
        self.policy = policy;
        self
    }

    /// The GEO configuration compactions re-run.
    pub fn geo_config(&self) -> &GeoConfig {
        &self.cfg
    }

    /// The active compaction policy.
    pub fn policy(&self) -> &CompactionPolicy {
        &self.policy
    }

    /// Vertex id space (monotone — grows with inserted vertices, never
    /// shrinks while an engine is attached).
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Physical edge-id space size (base + staging, tombstones included).
    pub fn physical_edges(&self) -> usize {
        self.base.num_edges() + self.staging.len()
    }

    /// Live edges (physical minus tombstones).
    pub fn live_edges(&self) -> usize {
        self.physical_edges() - self.tombstones.len()
    }

    /// Length of the staging tail.
    pub fn staging_len(&self) -> usize {
        self.staging.len()
    }

    /// Number of tombstoned ids.
    pub fn tombstone_count(&self) -> usize {
        self.tombstones.len()
    }

    /// The sorted tombstone list.
    pub fn tombstones(&self) -> &[EdgeId] {
        &self.tombstones
    }

    /// Staged fraction of the physical space.
    pub fn staging_fraction(&self) -> f64 {
        self.staging.len() as f64 / self.physical_edges().max(1) as f64
    }

    /// Dead fraction of the physical space.
    pub fn dead_fraction(&self) -> f64 {
        self.tombstones.len() as f64 / self.physical_edges().max(1) as f64
    }

    /// Completed compactions.
    pub fn compactions(&self) -> u32 {
        self.compactions
    }

    /// Permutation of the most recent GEO pass (init or compaction):
    /// `perm[new_position] = old_edge_id` in the list that pass consumed —
    /// for callers that want to audit or persist the ordering decision
    /// next to their own artifacts. Note [`Self::save`] does not need it
    /// (it writes the already-permuted base), so after [`Self::load`] this
    /// is empty until the next compaction.
    pub fn last_permutation(&self) -> &[EdgeId] {
        &self.last_perm
    }

    /// Is physical id `id` live (in range and not tombstoned)?
    pub fn is_live(&self, id: EdgeId) -> bool {
        (id as usize) < self.physical_edges() && self.tombstones.binary_search(&id).is_err()
    }

    /// Live degree of `v` (0 for ids beyond the known space).
    pub fn degree(&self, v: VertexId) -> u32 {
        self.deg.get(v as usize).copied().unwrap_or(0)
    }

    /// Physical id of the live edge `{u, v}`, if present.
    pub fn live_edge_of(&self, u: VertexId, v: VertexId) -> Option<EdgeId> {
        if u == v {
            return None;
        }
        let key = Edge::new(u, v).canonical();
        if (key.0 as usize) < self.base.num_vertices() {
            for (w, eid) in self.base.neighbors(key.0) {
                if w == key.1 && self.tombstones.binary_search(&eid).is_err() {
                    return Some(eid);
                }
            }
        }
        self.staged_index.get(&key).copied()
    }

    /// The chunk assignment of the current physical space at `k`
    /// partitions — O(1) metadata plus the borrowed tombstone list.
    pub fn assignment(&self, k: usize) -> StagedAssignment<'_> {
        StagedAssignment::new(Cep::new(self.physical_edges(), k), &self.tombstones)
    }

    /// The weighted (non-uniform boundary) assignment of the current
    /// physical space — the skew-aware counterpart of
    /// [`Self::assignment`]: the borrowed view plus the borrowed
    /// tombstone list.
    pub fn weighted_assignment<'a>(
        &'a self,
        view: &'a crate::partition::WeightedCepView,
    ) -> WeightedStagedAssignment<'a> {
        WeightedStagedAssignment::new(view, &self.tombstones)
    }

    /// Ingest a mutation batch under `k` partitions: tombstone deletions,
    /// stage insertions locality-aware, and derive the executable
    /// [`ChurnPlan`] transitioning `assignment(k)` from its pre-batch to
    /// its post-batch state. Mutations apply in order, so delete-then-
    /// reinsert of the same pair works within one batch.
    ///
    /// The expensive per-mutation work — duplicate lookups against the
    /// live edge set — runs as a read-only parallel pass over the
    /// pre-batch state (`cfg.threads`); the cheap sequential pass then
    /// reconciles in-batch ordering (same-batch deletes re-enable a pair
    /// via `newly_dead`), so the outcome is identical to a fully
    /// interleaved scan at any thread count.
    pub fn apply_batch(&mut self, batch: &MutationBatch, k: usize) -> (BatchOutcome, ChurnPlan) {
        let sp = crate::obs::span("phase:ingest");
        let cep0 = Cep::new(self.physical_edges(), k);
        let (out, nd) = self.ingest(batch);
        let cep1 = Cep::new(self.physical_edges(), k);
        let plan = ChurnPlan::derive(&cep0, &cep1, &nd);
        self.tombstones = merge_sorted_par(&self.tombstones, &nd, self.cfg.threads);
        sp.add("inserted", out.inserted as u64);
        sp.add("deleted", out.deleted as u64);
        sp.add("range_ops", plan.range_ops() as u64);
        (out, plan)
    }

    /// [`Self::apply_batch`] against **weighted** (non-uniform) chunk
    /// boundaries — the streaming half of skew-aware rebalancing.
    /// `bounds` is the live boundary array (`bounds[0] == 0`, last entry
    /// == [`Self::physical_edges`]); the batch's appended tail extends the
    /// last chunk in place (owners of pre-existing ids never shift), and
    /// the returned plan is derived by
    /// [`ChurnPlan::derive_weighted`]. The array is updated to cover the
    /// post-batch physical space.
    pub fn apply_batch_weighted(
        &mut self,
        batch: &MutationBatch,
        bounds: &mut Vec<u64>,
    ) -> (BatchOutcome, ChurnPlan) {
        assert_eq!(
            *bounds.last().expect("bounds non-empty") as usize,
            self.physical_edges(),
            "boundary array out of sync with the physical id space"
        );
        let sp = crate::obs::span("phase:ingest");
        let old = crate::partition::WeightedCepView::from_bounds(bounds.clone());
        let (out, nd) = self.ingest(batch);
        *bounds.last_mut().unwrap() = self.physical_edges() as u64;
        let new = crate::partition::WeightedCepView::from_bounds(bounds.clone());
        let plan = ChurnPlan::derive_weighted(&old, &new, &nd);
        self.tombstones = merge_sorted_par(&self.tombstones, &nd, self.cfg.threads);
        sp.add("inserted", out.inserted as u64);
        sp.add("deleted", out.deleted as u64);
        sp.add("range_ops", plan.range_ops() as u64);
        (out, plan)
    }

    /// The mutation core shared by [`Self::apply_batch`] and
    /// [`Self::apply_batch_weighted`]: tombstone deletions, stage accepted
    /// insertions locality-aware, and return the batch outcome plus the
    /// sorted newly-dead ids. Does **not** merge the tombstone list —
    /// callers derive their churn plan against the pre-merge state first.
    fn ingest(&mut self, batch: &MutationBatch) -> (BatchOutcome, Vec<EdgeId>) {
        let p0 = self.physical_edges();
        let mut out = BatchOutcome::default();
        let mut newly_dead: HashSet<EdgeId> = HashSet::new();
        let mut accepted: Vec<Edge> = Vec::new();
        let mut accepted_keys: HashSet<(VertexId, VertexId)> = HashSet::new();

        let muts: Vec<&EdgeMutation> = batch.iter().collect();
        let lookups: Vec<Option<EdgeId>> = {
            let this: &StagedGraph = self;
            par::par_map(this.cfg.threads, muts.len(), |i| match *muts[i] {
                EdgeMutation::Insert { u, v } if u != v => this.live_edge_of(u, v),
                _ => None,
            })
        };

        for (mi, m) in muts.iter().enumerate() {
            match **m {
                EdgeMutation::Delete { edge } => {
                    if (edge as usize) < p0 && self.is_live(edge) && newly_dead.insert(edge) {
                        let e = self.edge(edge);
                        self.deg[e.u as usize] -= 1;
                        self.deg[e.v as usize] -= 1;
                        if edge as usize >= self.base.num_edges() {
                            self.staged_index.remove(&e.canonical());
                        }
                        out.deleted += 1;
                    } else {
                        out.skipped_deletes += 1;
                    }
                }
                EdgeMutation::Insert { u, v } => {
                    if u == v {
                        out.skipped_inserts += 1;
                        continue;
                    }
                    let key = Edge::new(u, v).canonical();
                    let duplicate = accepted_keys.contains(&key)
                        || match lookups[mi] {
                            // deleted earlier in this batch ⇒ re-insertable
                            Some(eid) => !newly_dead.contains(&eid),
                            None => false,
                        };
                    if duplicate {
                        out.skipped_inserts += 1;
                    } else {
                        accepted_keys.insert(key);
                        accepted.push(Edge::new(u, v));
                        out.inserted += 1;
                    }
                }
            }
        }

        let mut nd: Vec<EdgeId> = newly_dead.into_iter().collect();
        nd.sort_unstable();

        // place accepted insertions near their neighborhoods (the window
        // seed skips the ids this very batch just tombstoned), then assign
        // them the next physical ids
        let placed = self.order_for_locality(&accepted, &nd);
        for e in &placed {
            let id = self.physical_edges() as EdgeId;
            let grow = e.u.max(e.v) as usize + 1;
            if grow > self.n {
                self.n = grow;
                self.deg.resize(self.n, 0);
            }
            self.deg[e.u as usize] += 1;
            self.deg[e.v as usize] += 1;
            self.staged_index.insert(e.canonical(), id);
            self.staging.push(*e);
        }

        (out, nd)
    }

    /// Derive the plan for a pure rescale `k → new_k` of the current
    /// state (no mutations): at most `k + k′ + 1` contiguous range moves,
    /// exactly as a static CEP rescale — tombstoned ids ride along inside
    /// their range.
    pub fn rescale_plan(&self, k: usize, new_k: usize) -> ChurnPlan {
        let cep = Cep::new(self.physical_edges(), k);
        ChurnPlan::derive(&cep, &cep.rescaled(new_k), &[])
    }

    /// Is the compaction budget spent?
    pub fn needs_compaction(&self) -> bool {
        self.policy.should_compact(
            self.staging.len(),
            self.tombstones.len(),
            self.physical_edges(),
        )
    }

    /// Fold tombstones and the staging tail back through a fresh GEO pass:
    /// the live edges become the new base, the physical id space is
    /// renumbered, and the staging/tombstone state resets. Engines must be
    /// rebuilt afterwards (this is the amortized-expensive event the
    /// policy budgets).
    pub fn compact(&mut self) {
        let sp = crate::obs::span("phase:compact");
        sp.add("live_edges", self.live_edges() as u64);
        sp.add("reclaimed", self.tombstones.len() as u64);
        sp.add("folded_staged", self.staging.len() as u64);
        let live = self.live_edge_vec();
        let el = EdgeList::from_vec(live);
        let csr = Csr::build_with(self.n, &el, self.cfg.threads);
        let g = Graph::from_parts(el, csr);
        let perm = {
            let gsp = crate::obs::span("phase:geo-pass");
            gsp.add("edges", g.num_edges() as u64);
            gsp.add("vertices", g.num_vertices() as u64);
            geo::order(&g, &self.cfg).into_perm()
        };
        self.base = g.permute_edges(&perm);
        self.last_perm = perm;
        self.staging.clear();
        self.staged_index.clear();
        self.tombstones.clear();
        self.compactions += 1;
    }

    /// Materialize the live graph (physical order, holes removed, vertex
    /// id space preserved) — for oracle comparisons and fresh-repartition
    /// baselines; the streaming path itself never calls this.
    pub fn as_graph(&self) -> Graph {
        let live = self.live_edge_vec();
        let el = EdgeList::from_vec(live);
        let csr = Csr::build_with(self.n, &el, self.cfg.threads);
        Graph::from_parts(el, csr)
    }

    /// Persist as a v2 `.egs` snapshot (physical list + staged-tail length
    /// + tombstone bitmap).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut phys: Vec<Edge> = Vec::with_capacity(self.physical_edges());
        phys.extend(self.base.edges().iter().copied());
        phys.extend(self.staging.iter().copied());
        let el = EdgeList::from_vec(phys);
        let csr = Csr::build_with(self.n, &el, self.cfg.threads);
        let g = Graph::from_parts(el, csr);
        io::save_binary_v2(&g, self.staging.len() as u64, &self.tombstones, path)
    }

    /// Spill the **base** edge list to disk and return a paged twin of
    /// this staged graph: same physical id space, same vertex space, same
    /// liveness. The base (the overwhelming bulk of the physical space)
    /// is served from the page cache; the staging tail and tombstone list
    /// stay resident on the twin — tombstone ids span base *and* staged
    /// ids, so they cannot live in the v1 base file. The twin prices
    /// bit-identically to `self` under every [`EdgeSource`] consumer
    /// (engine mirrors, quality sweeps, churn-plan execution).
    pub fn spill(&self, path: &Path, cfg: PagedConfig) -> Result<PagedEdges> {
        io::save_binary(&self.base, path)?;
        let mut pe = PagedEdges::open(path, cfg)?;
        pe.set_staging(self.staging.clone(), self.n);
        pe.set_tombstones(self.tombstones.clone());
        Ok(pe)
    }

    /// Load a `.egs` snapshot (v1 or v2) back into a staged graph. The
    /// base is **not** re-ordered — the snapshot's order is trusted, so a
    /// v1 file behaves as an already-ordered base with an empty tail.
    pub fn load(path: &Path, cfg: GeoConfig) -> Result<StagedGraph> {
        let snap = io::load_binary_v2(path)?;
        let n = snap.graph.num_vertices();
        let physical = snap.graph.num_edges();
        let staged_len = snap.staged_len as usize;
        if staged_len > physical {
            anyhow::bail!("staged tail ({staged_len}) longer than edge list ({physical})");
        }
        let base_m = physical - staged_len;
        let mut base_edges: Vec<Edge> = Vec::with_capacity(base_m);
        let mut staging: Vec<Edge> = Vec::with_capacity(staged_len);
        for (i, e) in snap.graph.edges().iter().enumerate() {
            if i < base_m {
                base_edges.push(*e);
            } else {
                staging.push(*e);
            }
        }
        let el = EdgeList::from_vec(base_edges);
        let csr = Csr::build_with(n, &el, cfg.threads);
        let base = Graph::from_parts(el, csr);

        let mut sg = StagedGraph {
            base,
            staging,
            tombstones: snap.tombstones,
            n,
            deg: vec![0; n],
            staged_index: HashMap::new(),
            cfg,
            policy: CompactionPolicy::default(),
            compactions: 0,
            last_perm: Vec::new(),
        };
        for id in 0..sg.physical_edges() as EdgeId {
            if sg.is_live(id) {
                let e = sg.edge(id);
                sg.deg[e.u as usize] += 1;
                sg.deg[e.v as usize] += 1;
                if id as usize >= sg.base.num_edges() {
                    sg.staged_index.insert(e.canonical(), id);
                }
            }
        }
        Ok(sg)
    }

    /// Live edges in physical order (chunked across the pool; chunk
    /// boundaries are fixed, so the concatenation is order-identical to a
    /// serial sweep).
    fn live_edge_vec(&self) -> Vec<Edge> {
        let p = self.physical_edges();
        par::par_reduce(
            self.cfg.threads,
            p,
            |r| {
                let mut chunk: Vec<Edge> = Vec::with_capacity(r.len());
                let mut t = self.tombstones.partition_point(|&d| (d as usize) < r.start);
                for id in r {
                    if t < self.tombstones.len() && self.tombstones[t] == id as EdgeId {
                        t += 1;
                        continue;
                    }
                    chunk.push(self.edge(id as EdgeId));
                }
                chunk
            },
            Vec::with_capacity(self.live_edges()),
            |mut acc, chunk| {
                acc.extend(chunk);
                acc
            },
        )
    }

    /// Order a batch of accepted insertions so that edges sharing a
    /// neighborhood land contiguously: a greedy chain over the GEO
    /// δ-window ([`TailWindow`]), seeded with the current live tail
    /// (excluding `extra_dead` — ids the in-flight batch just
    /// tombstoned). Edges adjacent to the window (or to an already-placed
    /// batch edge) are placed next; when the frontier dries up, the
    /// earliest unplaced edge seeds a new neighborhood. O(b · d̄) for a
    /// batch of b edges.
    fn order_for_locality(&self, inserts: &[Edge], extra_dead: &[EdgeId]) -> Vec<Edge> {
        let b = inserts.len();
        if b <= 1 {
            return inserts.to_vec();
        }
        let n_max = inserts
            .iter()
            .map(|e| e.u.max(e.v) as usize + 1)
            .max()
            .unwrap_or(0)
            .max(self.n);
        let delta = self.cfg.effective_delta(self.live_edges().max(1));
        let mut window = TailWindow::new(n_max, delta);
        // seed with the last δ live edges of the current physical list:
        // liveness over the bounded candidate tail (δ plus every
        // possibly-dead id caps how far back the last δ live ids reach)
        // is checked across the pool, then collected serially — same seed
        // as a backward scan, at any thread count
        let p = self.physical_edges();
        let dead_ub = self.tombstones.len() + extra_dead.len();
        let lo = p.saturating_sub(delta + dead_ub);
        let live_tail: Vec<bool> = par::par_map(self.cfg.threads, p - lo, |j| {
            let id = (lo + j) as EdgeId;
            self.is_live(id) && extra_dead.binary_search(&id).is_err()
        });
        let mut seed: Vec<Edge> = Vec::with_capacity(delta);
        for j in (0..p - lo).rev() {
            if seed.len() >= delta {
                break;
            }
            if live_tail[j] {
                seed.push(self.edge((lo + j) as EdgeId));
            }
        }
        for e in seed.iter().rev() {
            window.push(*e);
        }

        let mut by_vertex: HashMap<VertexId, Vec<usize>> = HashMap::new();
        for (i, e) in inserts.iter().enumerate() {
            by_vertex.entry(e.u).or_default().push(i);
            by_vertex.entry(e.v).or_default().push(i);
        }
        let mut placed = vec![false; b];
        let mut out: Vec<Edge> = Vec::with_capacity(b);
        let mut stack: Vec<usize> = Vec::new();
        let mut cursor = 0usize;
        while out.len() < b {
            // pick the next edge: window-adjacent from the frontier stack,
            // else the earliest unplaced edge seeds a new neighborhood
            let idx = loop {
                match stack.pop() {
                    Some(i) => {
                        let e = inserts[i];
                        if !placed[i] && (window.contains(e.u) || window.contains(e.v)) {
                            break i;
                        }
                    }
                    None => {
                        while placed[cursor] {
                            cursor += 1;
                        }
                        break cursor;
                    }
                }
            };
            placed[idx] = true;
            let e = inserts[idx];
            out.push(e);
            window.push(e);
            for w in [e.u, e.v] {
                if let Some(list) = by_vertex.get(&w) {
                    stack.extend(list.iter().copied().filter(|&j| !placed[j]));
                }
            }
        }
        out
    }
}

impl EdgeSource for StagedGraph {
    fn num_vertices(&self) -> usize {
        self.n
    }

    fn num_edges(&self) -> usize {
        self.physical_edges()
    }

    #[inline]
    fn edge(&self, id: EdgeId) -> Edge {
        let base_m = self.base.num_edges();
        if (id as usize) < base_m {
            self.base.edges()[id as usize]
        } else {
            self.staging[id as usize - base_m]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::erdos_renyi;
    use crate::util::rng::Rng;

    fn cfg() -> GeoConfig {
        GeoConfig { k_min: 2, k_max: 8, delta: None, seed: 1, ..Default::default() }
    }

    #[test]
    fn insert_delete_roundtrip_preserves_live_set() {
        let g = erdos_renyi(60, 200, 3);
        let m0 = g.num_edges();
        let mut sg = StagedGraph::new(g, cfg());
        assert_eq!(sg.live_edges(), m0);

        let mut batch = MutationBatch::new();
        batch.delete(5);
        batch.delete(5); // repeated → skipped
        batch.insert(0, 1_000); // new vertex
        let (out, plan) = sg.apply_batch(&batch, 4);
        assert_eq!(out.deleted, 1);
        assert_eq!(out.skipped_deletes, 1);
        assert_eq!(out.inserted, 1);
        assert_eq!(sg.live_edges(), m0);
        assert_eq!(sg.physical_edges(), m0 + 1);
        assert_eq!(sg.num_vertices(), 1_001);
        assert_eq!(sg.degree(1_000), 1);
        assert!(!sg.is_live(5));
        assert!(sg.live_edge_of(0, 1_000).is_some());
        assert_eq!(plan.retired_edges(), 1);
        assert_eq!(plan.appended_edges(), 1);
    }

    #[test]
    fn duplicate_inserts_are_skipped_but_reinsert_after_delete_works() {
        let g = GraphBuilder::new().edge(0, 1).edge(1, 2).edge(2, 3).build();
        let mut sg = StagedGraph::new(g, cfg());
        let eid = sg.live_edge_of(0, 1).unwrap();

        let mut b1 = MutationBatch::new();
        b1.insert(1, 0); // duplicate of live base edge (reversed)
        b1.insert(5, 5); // self loop
        let (out, _) = sg.apply_batch(&b1, 2);
        assert_eq!(out.inserted, 0);
        assert_eq!(out.skipped_inserts, 2);

        let mut b2 = MutationBatch::new();
        b2.delete(eid);
        b2.insert(0, 1); // same pair, deleted earlier in this batch
        let (out, _) = sg.apply_batch(&b2, 2);
        assert_eq!(out.deleted, 1);
        assert_eq!(out.inserted, 1);
        assert_eq!(sg.live_edges(), 3);
        // the live edge now resolves to the staged copy
        assert!(sg.live_edge_of(0, 1).unwrap() >= 3);

        // a second staged duplicate is rejected too
        let mut b3 = MutationBatch::new();
        b3.insert(0, 1);
        let (out, _) = sg.apply_batch(&b3, 2);
        assert_eq!(out.skipped_inserts, 1);
    }

    #[test]
    fn compaction_folds_and_renumbers() {
        let g = erdos_renyi(80, 400, 7);
        let mut sg = StagedGraph::new(g, cfg()).with_policy(CompactionPolicy::with_budget(0.05));
        let mut rng = Rng::new(9);
        let mut batch = MutationBatch::new();
        for _ in 0..60 {
            batch.insert(rng.below(80) as u32, rng.below(80) as u32);
        }
        for id in [0u64, 7, 13] {
            batch.delete(id);
        }
        let (out, _) = sg.apply_batch(&batch, 4);
        assert!(out.inserted > 0 && out.deleted == 3);
        assert!(sg.needs_compaction());
        let live_before = sg.live_edges();
        let deg_before: Vec<u32> = (0..sg.num_vertices() as u32).map(|v| sg.degree(v)).collect();
        sg.compact();
        assert_eq!(sg.compactions(), 1);
        assert_eq!(sg.live_edges(), live_before);
        assert_eq!(sg.physical_edges(), live_before);
        assert_eq!(sg.staging_len(), 0);
        assert_eq!(sg.tombstone_count(), 0);
        assert!(!sg.needs_compaction());
        assert_eq!(sg.last_permutation().len(), live_before);
        let deg_after: Vec<u32> = (0..sg.num_vertices() as u32).map(|v| sg.degree(v)).collect();
        assert_eq!(deg_before, deg_after, "compaction must not change the live graph");
    }

    #[test]
    fn locality_staging_clusters_neighborhoods() {
        // two independent 6-edge stars interleaved in the batch: the
        // locality placer must de-interleave them into contiguous runs
        let g = erdos_renyi(40, 160, 1);
        let mut sg = StagedGraph::new(g, cfg());
        let mut batch = MutationBatch::new();
        for i in 0..6u32 {
            batch.insert(100, 110 + i);
            batch.insert(200, 210 + i);
        }
        let p0 = sg.physical_edges();
        let (out, _) = sg.apply_batch(&batch, 4);
        assert_eq!(out.inserted, 12);
        let hubs: Vec<u32> = (p0..sg.physical_edges())
            .map(|id| {
                let e = sg.edge(id as EdgeId);
                e.u.min(e.v)
            })
            .collect();
        // count hub switches along the tail: perfect clustering = 1
        let switches = hubs.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(
            switches <= 2,
            "staging tail interleaves neighborhoods: {hubs:?}"
        );
    }

    #[test]
    fn weighted_batch_keeps_interior_boundaries_and_stays_exact() {
        use crate::partition::{PartitionAssignment, WeightedCepView};

        let g = erdos_renyi(60, 300, 11);
        let m0 = g.num_edges() as u64;
        let mut sg = StagedGraph::new(g, cfg());
        // a deliberately skewed boundary array over the initial space
        let mut bounds = vec![0, m0 / 10, m0 / 2, m0];
        let before = bounds.clone();

        let mut batch = MutationBatch::new();
        let mut rng = Rng::new(4);
        for _ in 0..25 {
            batch.insert(rng.below(60) as u32, rng.below(60) as u32);
        }
        batch.delete(3);
        batch.delete(4);
        let (out, plan) = sg.apply_batch_weighted(&batch, &mut bounds);
        assert_eq!(out.deleted, 2);
        assert!(out.inserted > 0);

        // interior boundaries are untouched; only the tail grew
        assert_eq!(&bounds[..bounds.len() - 1], &before[..before.len() - 1]);
        assert_eq!(*bounds.last().unwrap() as usize, sg.physical_edges());
        // appended ids all land in the last chunk, no moves among old ids
        assert!(plan.moves.is_empty(), "tail append must not shift owners");
        assert_eq!(plan.appended_edges(), out.inserted as u64);
        assert!(plan.appends.iter().all(|(p, _)| *p == 2));

        // the weighted staged assignment sees the post-batch state
        let view = WeightedCepView::from_bounds(bounds.clone());
        let wa = sg.weighted_assignment(&view);
        assert_eq!(wa.num_live_edges(), sg.live_edges() as u64);
        assert_eq!(
            wa.sizes().iter().sum::<u64>(),
            sg.live_edges() as u64
        );
    }

    /// A paged spill twin answers every physical-id query — endpoints,
    /// liveness, live count — identically to the staged graph it mirrors,
    /// even through a cache far smaller than the base list.
    #[test]
    fn spill_twin_matches_staged_state() {
        let g = erdos_renyi(70, 350, 13);
        let mut sg = StagedGraph::new(g, cfg());
        let mut rng = Rng::new(8);
        let mut batch = MutationBatch::new();
        for _ in 0..20 {
            batch.insert(rng.below(70) as u32, rng.below(70) as u32);
        }
        for id in [2u64, 9, 41] {
            batch.delete(id);
        }
        sg.apply_batch(&batch, 4);
        let path =
            std::env::temp_dir().join(format!("egs_staged_spill_{}.egs", std::process::id()));
        let paged_cfg =
            crate::graph::PagedConfig::default().with_page_bytes(64).with_cache_bytes(256);
        let pe = sg.spill(&path, paged_cfg).unwrap();
        assert_eq!(EdgeSource::num_edges(&pe), sg.physical_edges());
        assert_eq!(EdgeSource::num_vertices(&pe), sg.num_vertices());
        assert_eq!(pe.num_live_edges(), sg.live_edges());
        for id in 0..sg.physical_edges() as EdgeId {
            assert_eq!(pe.edge(id), sg.edge(id), "edge {id}");
            assert_eq!(pe.is_live(id), sg.is_live(id), "liveness {id}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn as_graph_matches_live_view() {
        let g = erdos_renyi(50, 150, 5);
        let mut sg = StagedGraph::new(g, cfg());
        let mut batch = MutationBatch::new();
        batch.insert(1, 45);
        batch.delete(0);
        sg.apply_batch(&batch, 3);
        let live = sg.as_graph();
        assert_eq!(live.num_edges(), sg.live_edges());
        assert_eq!(live.num_vertices(), sg.num_vertices());
        // degrees agree between the incremental counters and the rebuild
        for v in 0..live.num_vertices() as u32 {
            assert_eq!(live.degree(v) as u32, sg.degree(v), "vertex {v}");
        }
    }
}
