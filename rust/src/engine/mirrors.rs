//! Vertex master/mirror placement over an edge partitioning.
//!
//! In a vertex-cut engine every partition materializes the vertices of its
//! edges; one replica per vertex is the **master** (owner of the canonical
//! value), the rest are mirrors. Masters are placed on the replica
//! partition chosen by a degree-independent hash, which balances master
//! counts across partitions (PowerGraph's strategy).
//!
//! The layout is built once from any [`PartitionAssignment`] and then kept
//! current across rescales by **executing migration plans**
//! ([`PartitionLayout::apply_plan`]): moved edge-id ranges are spliced
//! between per-partition edge sets, only the touched partitions rebuild
//! their local tables, and master/mirror state is re-derived only for the
//! vertices whose replica set actually changed — never a full rebuild.
//!
//! Ownership itself is **interval-set metadata**
//! ([`crate::partition::intervals::IdRangeSet`]): each partition's edge-id
//! set is a sorted, coalesced list of contiguous ranges, so a
//! chunk-contiguous layout (CEP, streaming staged chunks) carries O(k)
//! resident metadata — one interval per partition — instead of 8 B/edge,
//! and a plan's range move executes as two interval splices with **no
//! per-edge work**. Building from a chunked assignment is O(k) via
//! [`PartitionAssignment::as_chunks`]; scattered assignments coalesce
//! maximal runs (O(m) build time, O(runs) memory).
//!
//! Streaming graphs extend the same machinery: the layout is generic over
//! [`EdgeSource`] (a [`crate::graph::Graph`] or a
//! [`crate::stream::StagedGraph`]) and executes [`ChurnPlan`]s
//! ([`PartitionLayout::apply_churn`]). Tombstoned
//! ids stay in their nominal owner's interval — so every later move
//! remains one contiguous range — but are skipped whenever a partition
//! materializes its local tables: a **retirement** just marks the owner
//! for rebuild, an **append** admits a freshly staged range, and
//! rebalancing moves splice exactly like a rescale plan. The vertex id
//! space may grow.

use crate::graph::EdgeSource;
use crate::partition::intervals::IdRangeSet;
use crate::partition::PartitionAssignment;
use crate::scaling::migration::MigrationPlan;
use crate::stream::plan::ChurnPlan;
use crate::util::rng::mix64;
use crate::{EdgeId, VertexId};
use std::ops::Range;

/// Layout state: per-partition vertex sets, owned edge-id intervals, local
/// edge endpoints and the global master assignment. Mutated in place by
/// [`PartitionLayout::apply_plan`].
pub struct PartitionLayout {
    k: usize,
    n: usize,
    /// sorted global vertex ids present in each partition
    vertices: Vec<Vec<VertexId>>,
    /// per-partition directed edge endpoints in local indices (both
    /// directions of each undirected edge)
    local_src: Vec<Vec<i32>>,
    local_dst: Vec<Vec<i32>>,
    /// master partition per vertex (u32::MAX for isolated vertices)
    master: Vec<u32>,
    /// number of replicas per vertex
    replicas: Vec<u32>,
    /// global edge ids owned by each partition as interval sets — the
    /// substrate the range moves of a migration/churn plan splice between
    /// partitions. On the streaming path the intervals include tombstoned
    /// ids (they stay with their nominal owner so moves remain whole
    /// ranges) but dead ids are skipped when local tables materialize.
    /// O(k + ranges) resident metadata: one interval per partition on
    /// chunk-contiguous layouts.
    edge_ids: Vec<IdRangeSet>,
    /// sorted replica partition list per vertex (incrementally patched)
    replica_parts: Vec<Vec<u32>>,
}

impl PartitionLayout {
    /// Build the layout for `(g, part)` from any assignment view over any
    /// edge source. Chunked assignments ([`PartitionAssignment::as_chunks`])
    /// seed the ownership intervals in O(k); scattered assignments
    /// coalesce maximal runs. Dead ids (tombstones of a staged assignment)
    /// stay with their nominal owner but never reach its local tables.
    pub fn build<E, P>(g: &E, part: &P) -> PartitionLayout
    where
        E: EdgeSource + ?Sized,
        P: PartitionAssignment + ?Sized,
    {
        let k = part.k();
        let n = g.num_vertices();
        debug_assert_eq!(part.num_edges() as usize, g.num_edges());
        let edge_ids: Vec<IdRangeSet> = match part.as_chunks() {
            Some(chunks) => {
                debug_assert_eq!(chunks.len(), k);
                chunks.into_iter().map(IdRangeSet::from_range).collect()
            }
            None => {
                let mut sets = vec![IdRangeSet::new(); k];
                for eid in 0..g.num_edges() as EdgeId {
                    sets[part.partition_of(eid) as usize].push_back(eid);
                }
                sets
            }
        };
        let mut layout = PartitionLayout {
            k,
            n,
            vertices: vec![Vec::new(); k],
            local_src: vec![Vec::new(); k],
            local_dst: vec![Vec::new(); k],
            master: vec![u32::MAX; n],
            replicas: vec![0u32; n],
            edge_ids,
            replica_parts: vec![Vec::new(); n],
        };
        for p in 0..k {
            layout.rebuild_partition(p, g, part);
        }
        for p in 0..k {
            let vs = std::mem::take(&mut layout.vertices[p]);
            for &v in &vs {
                layout.replica_parts[v as usize].push(p as u32);
            }
            layout.vertices[p] = vs;
        }
        for v in 0..n as VertexId {
            layout.refresh_vertex(v);
        }
        layout
    }

    /// Execute a migration plan in place, transitioning the layout from
    /// its current assignment to the one the plan encodes (`k` becomes
    /// `new_k`). The ownership edit is pure interval splicing — an
    /// O(log r) locate plus an O(r) interval edit per range op, no
    /// per-edge work — and the rest is proportional to
    /// the touched partitions and the vertices whose replica set changed;
    /// untouched partitions keep their tables. Returns the ids (< `new_k`)
    /// of partitions whose local state changed, ascending.
    ///
    /// Panics when the plan is inconsistent with the current layout (a
    /// moved range not wholly owned by its source, or a removed partition
    /// still owning edges).
    pub fn apply_plan<E, P>(&mut self, g: &E, plan: &MigrationPlan, new_part: &P) -> Vec<usize>
    where
        E: EdgeSource + ?Sized,
        P: PartitionAssignment + ?Sized,
    {
        let new_k = new_part.k();
        let old_k = self.k;
        let grown = self.grow_partitions(new_k);

        // 1. splice moved ranges out of their source intervals
        let mut changed = vec![false; grown];
        for mv in &plan.moves {
            let (s, d) = (mv.src as usize, mv.dst as usize);
            assert!(s < grown && d < grown, "plan references partition out of range");
            if s == d || mv.is_empty() {
                continue;
            }
            self.edge_ids[s].splice_out(mv.edges.clone());
            changed[s] = true;
            changed[d] = true;
        }
        // 2. admit them at their destinations; adjacent moves landing on
        //    the same destination are coalesced into single splices
        for (d, span) in plan.dst_spans() {
            self.edge_ids[d as usize].splice_in(span);
        }

        self.finish_apply(g, new_part, &changed, old_k, new_k)
    }

    /// Execute a **churn plan** in place: mark retired (tombstoned) ranges
    /// for rebuild at their owner, splice rebalancing moves, and admit
    /// appended (freshly staged) ranges — the streaming counterpart of
    /// [`Self::apply_plan`]. Retired ids stay in the owner's intervals
    /// (they are dead under `new_part` and vanish from its local tables at
    /// rebuild); this keeps every subsequent move a single contiguous
    /// range. The vertex id space may have grown (`g.num_vertices()`
    /// governs); work remains proportional to the touched partitions.
    /// Returns the ids (< `new_part.k()`) of partitions whose local state
    /// changed, ascending.
    pub fn apply_churn<E, P>(&mut self, g: &E, plan: &ChurnPlan, new_part: &P) -> Vec<usize>
    where
        E: EdgeSource + ?Sized,
        P: PartitionAssignment + ?Sized,
    {
        let new_k = new_part.k();
        let old_k = self.k;
        let grown = self.grow_partitions(new_k);
        // the mutated source may have introduced new vertices
        let new_n = g.num_vertices();
        if new_n > self.n {
            self.master.resize(new_n, u32::MAX);
            self.replicas.resize(new_n, 0);
            self.replica_parts.resize_with(new_n, Vec::new);
            self.n = new_n;
        }

        let mut changed = vec![false; grown];
        // 1. retire: the owner keeps the ids but must drop the edges from
        //    its local tables — mark it for rebuild
        for (src, r) in &plan.retires {
            let s = *src as usize;
            assert!(s < grown, "churn plan retires from partition out of range");
            debug_assert!(r.start < r.end, "empty retire range");
            changed[s] = true;
        }
        // 2. splice rebalancing moves (pre-existing ids, dead included):
        //    interval edits out of every source, coalesced same-destination
        //    spans back in
        for mv in &plan.moves.moves {
            let (s, d) = (mv.src as usize, mv.dst as usize);
            assert!(s < grown && d < grown, "churn plan references partition out of range");
            if s == d || mv.is_empty() {
                continue;
            }
            self.edge_ids[s].splice_out(mv.edges.clone());
            changed[s] = true;
            changed[d] = true;
        }
        for (d, span) in plan.moves.dst_spans() {
            self.edge_ids[d as usize].splice_in(span);
        }
        // 3. append: admit freshly staged ranges (ids beyond every
        //    pre-existing id, so each lands as the owner's last interval —
        //    coalescing with its chunk when adjacent)
        for (dst, r) in &plan.appends {
            let d = *dst as usize;
            assert!(d < grown, "churn plan appends to partition out of range");
            let set = &mut self.edge_ids[d];
            if let Some(last) = set.ranges().last() {
                assert!(
                    last.end <= r.start,
                    "appended range {}..{} not beyond partition {d}'s ids",
                    r.start,
                    r.end
                );
            }
            set.splice_in(r.clone());
            changed[d] = true;
        }

        self.finish_apply(g, new_part, &changed, old_k, new_k)
    }

    /// Grow the per-partition arrays to `max(new_k, k)`; returns that size.
    fn grow_partitions(&mut self, new_k: usize) -> usize {
        let grown = new_k.max(self.k);
        if grown > self.k {
            self.vertices.resize_with(grown, Vec::new);
            self.local_src.resize_with(grown, Vec::new);
            self.local_dst.resize_with(grown, Vec::new);
            self.edge_ids.resize_with(grown, IdRangeSet::new);
        }
        grown
    }

    /// Shared tail of plan execution: rebuild local tables of touched
    /// partitions, patch replica sets for vertices gained/lost, enforce
    /// that a shrink drained the removed partitions, and re-derive
    /// master/mirror info for exactly the affected vertices.
    fn finish_apply<E, P>(
        &mut self,
        g: &E,
        part: &P,
        changed: &[bool],
        old_k: usize,
        new_k: usize,
    ) -> Vec<usize>
    where
        E: EdgeSource + ?Sized,
        P: PartitionAssignment + ?Sized,
    {
        let mut dirty: Vec<VertexId> = Vec::new();
        for (p, &was_changed) in changed.iter().enumerate() {
            if !was_changed {
                continue;
            }
            let old_verts = std::mem::take(&mut self.vertices[p]);
            self.rebuild_partition(p, g, part);
            let (removed, added) = diff_sorted(&old_verts, &self.vertices[p]);
            for v in removed {
                let parts = &mut self.replica_parts[v as usize];
                match parts.binary_search(&(p as u32)) {
                    Ok(i) => {
                        parts.remove(i);
                    }
                    Err(_) => panic!("replica set of vertex {v} lacked partition {p}"),
                }
                dirty.push(v);
            }
            for v in added {
                let parts = &mut self.replica_parts[v as usize];
                match parts.binary_search(&(p as u32)) {
                    Err(i) => parts.insert(i, p as u32),
                    Ok(_) => panic!("replica set of vertex {v} already had partition {p}"),
                }
                dirty.push(v);
            }
        }

        // shrink: removed partitions must have been drained by the plan
        if new_k < old_k {
            for (p, set) in self.edge_ids.iter().enumerate().take(old_k).skip(new_k) {
                assert!(
                    set.is_empty(),
                    "partition {p} still owns {} edges after scale-in plan",
                    set.len()
                );
            }
            self.vertices.truncate(new_k);
            self.local_src.truncate(new_k);
            self.local_dst.truncate(new_k);
            self.edge_ids.truncate(new_k);
        }
        self.k = new_k;

        // re-derive master/mirror info for affected vertices only
        dirty.sort_unstable();
        dirty.dedup();
        for v in dirty {
            self.refresh_vertex(v);
        }

        changed
            .iter()
            .enumerate()
            .filter(|&(p, &c)| c && p < new_k)
            .map(|(p, _)| p)
            .collect()
    }

    /// Recompute partition `p`'s vertex set and local edge arrays from its
    /// owned intervals, walking ranges and indexing the edge source by id
    /// within each range; dead (tombstoned) ids are skipped.
    fn rebuild_partition<E, P>(&mut self, p: usize, g: &E, part: &P)
    where
        E: EdgeSource + ?Sized,
        P: PartitionAssignment + ?Sized,
    {
        let mut present: std::collections::BTreeSet<VertexId> = Default::default();
        for r in self.edge_ids[p].ranges() {
            for eid in r.clone() {
                if !part.is_live(eid) {
                    continue;
                }
                let e = g.edge(eid);
                present.insert(e.u);
                present.insert(e.v);
            }
        }
        let verts: Vec<VertexId> = present.into_iter().collect();
        let lindex: std::collections::HashMap<VertexId, i32> =
            verts.iter().enumerate().map(|(i, &v)| (v, i as i32)).collect();
        let src = &mut self.local_src[p];
        let dst = &mut self.local_dst[p];
        src.clear();
        dst.clear();
        for r in self.edge_ids[p].ranges() {
            for eid in r.clone() {
                if !part.is_live(eid) {
                    continue;
                }
                let e = g.edge(eid);
                let lu = lindex[&e.u];
                let lv = lindex[&e.v];
                src.push(lu);
                dst.push(lv);
                src.push(lv);
                dst.push(lu);
            }
        }
        self.vertices[p] = verts;
    }

    /// Re-derive replica count and master placement of `v` from its
    /// (sorted) replica partition list — same hash pick as a fresh build,
    /// so incremental updates are bit-identical to rebuilding.
    fn refresh_vertex(&mut self, v: VertexId) {
        let parts = &self.replica_parts[v as usize];
        self.replicas[v as usize] = parts.len() as u32;
        self.master[v as usize] = if parts.is_empty() {
            u32::MAX
        } else {
            parts[(mix64(v as u64) % parts.len() as u64) as usize]
        };
    }

    /// Number of partitions.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of global vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Sorted global vertices of partition `p`.
    pub fn vertices_of(&self, p: usize) -> &[VertexId] {
        &self.vertices[p]
    }

    /// Owned edge-id intervals of partition `p`: sorted, coalesced,
    /// non-overlapping ranges. On the streaming path the intervals include
    /// tombstoned ids — check the assignment's `is_live` when walking
    /// them. Exactly one interval per partition on chunk-contiguous
    /// layouts.
    pub fn owned_ranges(&self, p: usize) -> &[Range<EdgeId>] {
        self.edge_ids[p].ranges()
    }

    /// Flattened iterator over the owned edge ids of partition `p`
    /// (ascending) — debug/test convenience; hot paths walk
    /// [`Self::owned_ranges`].
    pub fn owned_edge_ids(&self, p: usize) -> impl Iterator<Item = EdgeId> + '_ {
        self.edge_ids[p].iter()
    }

    /// Number of owned edge ids of partition `p` (tombstoned ids included
    /// on the streaming path) — O(1).
    pub fn num_owned_edges(&self, p: usize) -> u64 {
        self.edge_ids[p].len()
    }

    /// Ownership intervals of partition `p` — the per-partition metadata
    /// footprint the coordinator audits as `range_count`.
    pub fn range_count(&self, p: usize) -> usize {
        self.edge_ids[p].num_ranges()
    }

    /// Total ownership intervals across all partitions; ≤ k on
    /// chunk-contiguous layouts and ≤ k + applied range ops after any plan.
    pub fn total_ranges(&self) -> usize {
        self.edge_ids.iter().map(|s| s.num_ranges()).sum()
    }

    /// Resident bytes of the ownership metadata across all partitions
    /// (what a `Vec<Vec<EdgeId>>` substrate would charge 8 B/edge for).
    pub fn metadata_bytes(&self) -> usize {
        self.edge_ids.iter().map(|s| s.metadata_bytes()).sum()
    }

    /// Local directed source endpoints of partition `p`.
    pub fn src_of(&self, p: usize) -> &[i32] {
        &self.local_src[p]
    }

    /// Local directed destination endpoints of partition `p`.
    pub fn dst_of(&self, p: usize) -> &[i32] {
        &self.local_dst[p]
    }

    /// Master partition of vertex `v`.
    pub fn master_of(&self, v: VertexId) -> u32 {
        self.master[v as usize]
    }

    /// Replica count of vertex `v`.
    pub fn replicas_of(&self, v: VertexId) -> u32 {
        self.replicas[v as usize]
    }

    /// Replication factor implied by the layout (cross-check with
    /// [`crate::partition::quality::replication_factor`]).
    pub fn rf(&self) -> f64 {
        self.replicas.iter().map(|&r| r as u64).sum::<u64>() as f64 / self.n as f64
    }

    /// Total mirrors (replicas beyond the master).
    pub fn num_mirrors(&self) -> u64 {
        self.replicas.iter().map(|&r| (r.max(1) - 1) as u64).sum()
    }
}

/// Diff two sorted vertex lists into `(removed, added)`.
fn diff_sorted(old: &[VertexId], new: &[VertexId]) -> (Vec<VertexId>, Vec<VertexId>) {
    let (mut removed, mut added) = (Vec::new(), Vec::new());
    let (mut i, mut j) = (0usize, 0usize);
    while i < old.len() || j < new.len() {
        match (old.get(i), new.get(j)) {
            (Some(&a), Some(&b)) if a == b => {
                i += 1;
                j += 1;
            }
            (Some(&a), Some(&b)) if a < b => {
                removed.push(a);
                i += 1;
            }
            (Some(_), Some(&b)) => {
                added.push(b);
                j += 1;
            }
            (Some(&a), None) => {
                removed.push(a);
                i += 1;
            }
            (None, Some(&b)) => {
                added.push(b);
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
    (removed, added)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::erdos_renyi;
    use crate::partition::quality::replication_factor;
    use crate::partition::{cep::Cep, CepView, EdgePartition};
    use crate::util::proptest::check;

    #[test]
    fn masters_are_replica_partitions() {
        let g = erdos_renyi(100, 400, 1);
        let part = EdgePartition::from_cep(&Cep::new(g.num_edges(), 5));
        let l = PartitionLayout::build(&g, &part);
        for v in 0..g.num_vertices() as VertexId {
            let m = l.master_of(v);
            assert!(l.vertices_of(m as usize).binary_search(&v).is_ok());
        }
    }

    #[test]
    fn rf_matches_quality_metric() {
        let g = erdos_renyi(120, 600, 2);
        let part = EdgePartition::from_cep(&Cep::new(g.num_edges(), 7));
        let l = PartitionLayout::build(&g, &part);
        let rf = replication_factor(&g, &part);
        assert!((l.rf() - rf).abs() < 1e-9);
    }

    #[test]
    fn both_directions_materialized() {
        let g = GraphBuilder::new().edge(0, 1).build();
        let part = EdgePartition::new(1, vec![0]);
        let l = PartitionLayout::build(&g, &part);
        assert_eq!(l.src_of(0).len(), 2);
        assert_eq!(l.src_of(0), &[0, 1]);
        assert_eq!(l.dst_of(0), &[1, 0]);
    }

    #[test]
    fn mirror_count_consistency() {
        let g = erdos_renyi(80, 300, 3);
        let part = EdgePartition::from_cep(&Cep::new(g.num_edges(), 4));
        let l = PartitionLayout::build(&g, &part);
        let total_replicas: u64 =
            (0..4).map(|p| l.vertices_of(p).len() as u64).sum();
        let masters = (0..g.num_vertices() as VertexId)
            .filter(|&v| l.master_of(v) != u32::MAX)
            .count() as u64;
        assert_eq!(l.num_mirrors(), total_replicas - masters);
    }

    #[test]
    fn build_from_view_matches_build_from_vector() {
        let g = erdos_renyi(90, 420, 4);
        let c = Cep::new(g.num_edges(), 6);
        let a = PartitionLayout::build(&g, &CepView::new(c));
        let b = PartitionLayout::build(&g, &EdgePartition::from_cep(&c));
        assert_layouts_equal(&a, &b);
    }

    /// A chunked build costs one interval per partition, never per-edge
    /// metadata.
    #[test]
    fn chunked_build_is_one_interval_per_partition() {
        let g = erdos_renyi(100, 500, 6);
        let k = 8;
        let l = PartitionLayout::build(&g, &CepView::new(Cep::new(g.num_edges(), k)));
        for p in 0..k {
            assert!(l.range_count(p) <= 1, "partition {p}");
        }
        assert!(l.total_ranges() <= k);
        // interval metadata is orders of magnitude below 8 B/edge
        assert!(l.metadata_bytes() < 8 * g.num_edges());
    }

    fn assert_layouts_equal(a: &PartitionLayout, b: &PartitionLayout) {
        assert_eq!(a.k(), b.k());
        assert_eq!(a.num_vertices(), b.num_vertices());
        for p in 0..a.k() {
            assert_eq!(a.vertices_of(p), b.vertices_of(p), "vertices of {p}");
            assert_eq!(a.owned_ranges(p), b.owned_ranges(p), "ranges of {p}");
            assert_eq!(a.src_of(p), b.src_of(p), "src of {p}");
            assert_eq!(a.dst_of(p), b.dst_of(p), "dst of {p}");
        }
        for v in 0..a.num_vertices() as VertexId {
            assert_eq!(a.master_of(v), b.master_of(v), "master of {v}");
            assert_eq!(a.replicas_of(v), b.replicas_of(v), "replicas of {v}");
        }
    }

    /// Core incremental-migration invariant: applying a plan yields the
    /// exact layout a fresh build of the new assignment would produce —
    /// across CEP chains (scale out/in) and scattered diffs.
    #[test]
    fn apply_plan_matches_fresh_build() {
        check(0xA11F, 12, |rng| {
            let g = erdos_renyi(
                60 + rng.below_usize(120),
                250 + rng.below_usize(900),
                rng.next_u64(),
            );
            let m = g.num_edges();
            let mut k = 2 + rng.below_usize(6);
            let mut view = CepView::new(Cep::new(m, k));
            let mut layout = PartitionLayout::build(&g, &view);
            for _ in 0..4 {
                let up = rng.chance(0.5) && k < 12;
                let new_k = if up { k + 1 + rng.below_usize(2) } else { (k - 1).max(1) };
                let next = CepView::new(view.cep().rescaled(new_k));
                let plan =
                    crate::scaling::migration::MigrationPlan::between_ceps(view.cep(), next.cep());
                layout.apply_plan(&g, &plan, &next);
                let fresh = PartitionLayout::build(&g, &next);
                assert_layouts_equal(&layout, &fresh);
                // chunk-contiguous target: intervals coalesce back to one
                // per partition, so metadata stays O(k) across the chain
                assert!(
                    layout.total_ranges() <= new_k,
                    "k={new_k}: {} intervals resident",
                    layout.total_ranges()
                );
                view = next;
                k = new_k;
            }
        });
    }

    /// Scattered (non-chunked) plans through both growth and scale-in:
    /// the controller drives exactly this shape for bvc/1d/ginger, where a
    /// Preempt event shrinks k and the diff plan must drain the removed
    /// partitions.
    #[test]
    fn apply_plan_handles_scattered_diffs() {
        check(0xA11E, 10, |rng| {
            let g = erdos_renyi(70, 350, rng.next_u64());
            let m = g.num_edges();
            let k0 = 2 + rng.below_usize(6);
            let k1 = 2 + rng.below_usize(6); // freely above or below k0
            let old = EdgePartition::new(
                k0,
                (0..m).map(|_| rng.below(k0 as u64) as u32).collect(),
            );
            let new = EdgePartition::new(
                k1,
                (0..m).map(|_| rng.below(k1 as u64) as u32).collect(),
            );
            let plan = crate::scaling::migration::MigrationPlan::diff(&old, &new);
            let mut layout = PartitionLayout::build(&g, &old);
            let changed = layout.apply_plan(&g, &plan, &new);
            let fresh = PartitionLayout::build(&g, &new);
            assert_layouts_equal(&layout, &fresh);
            // every changed partition is within the new k
            assert!(changed.iter().all(|&p| p < new.k));
        });
    }

    /// Satellite acceptance: starting from a chunk-contiguous layout
    /// (≤ k intervals), every executed splice grows the resident interval
    /// count by at most one, so after any rescale sequence
    /// `total_ranges ≤ k_max + applied range ops` — the metadata never
    /// silently degrades to per-edge scale.
    #[test]
    fn range_count_bounded_by_k_plus_applied_ops() {
        check(0x1D5E, 10, |rng| {
            let g = erdos_renyi(80, 400, rng.next_u64());
            let m = g.num_edges();
            let k0 = 2 + rng.below_usize(6);
            let mut cur = EdgePartition::from_cep(&Cep::new(m, k0));
            let mut layout = PartitionLayout::build(&g, &cur);
            let mut k_max = k0;
            let mut applied_ops = 0usize;
            for _ in 0..3 {
                let k1 = 2 + rng.below_usize(8);
                k_max = k_max.max(k1);
                // scatter a fraction of edges to random owners so the plan
                // fragments intervals instead of rebuilding chunks
                let mut assign: Vec<u32> =
                    (0..m as u64).map(|i| cur.partition_of(i)).collect();
                for _ in 0..rng.below_usize(40) {
                    let i = rng.below_usize(m);
                    assign[i] = rng.below(k1 as u64) as u32;
                }
                for a in assign.iter_mut() {
                    if (*a as usize) >= k1 {
                        *a = (k1 - 1) as u32;
                    }
                }
                let next = EdgePartition::new(k1, assign);
                let plan = crate::scaling::migration::MigrationPlan::diff(&cur, &next);
                // one splice_out per move, one splice_in per coalesced
                // destination span
                applied_ops += plan.num_moves() + plan.dst_spans().len();
                layout.apply_plan(&g, &plan, &next);
                cur = next;
                assert!(
                    layout.total_ranges() <= k_max + applied_ops,
                    "{} intervals > k_max {k_max} + ops {applied_ops}",
                    layout.total_ranges()
                );
            }
        });
    }

    /// Streaming counterpart of `apply_plan_matches_fresh_build`: chains
    /// of churn batches (inserts growing the id — and vertex — space,
    /// tombstoning deletes) interleaved with rescales, applied
    /// incrementally, must equal a fresh build of the staged assignment.
    #[test]
    fn apply_churn_matches_fresh_build() {
        use crate::ordering::geo::GeoConfig;
        use crate::stream::{MutationBatch, StagedGraph};

        check(0xC19A, 8, |rng| {
            let g = erdos_renyi(
                50 + rng.below_usize(100),
                200 + rng.below_usize(700),
                rng.next_u64(),
            );
            let n0 = g.num_vertices() as u64;
            let cfg = GeoConfig { k_min: 2, k_max: 8, delta: None, seed: 3, ..Default::default() };
            let mut sg = StagedGraph::new(g, cfg);
            let mut k = 2 + rng.below_usize(5);
            let mut layout = {
                let assign = sg.assignment(k);
                PartitionLayout::build(&sg, &assign)
            };
            for _ in 0..4 {
                let mut batch = MutationBatch::new();
                for _ in 0..rng.below_usize(40) {
                    // occasionally grow the vertex space
                    let u = rng.below(n0) as u32;
                    let v = if rng.chance(0.1) {
                        (n0 + rng.below(8)) as u32
                    } else {
                        rng.below(n0) as u32
                    };
                    batch.insert(u, v);
                }
                for _ in 0..rng.below_usize(12) {
                    batch.delete(rng.below(sg.physical_edges() as u64));
                }
                let (_, plan) = sg.apply_batch(&batch, k);
                {
                    let assign = sg.assignment(k);
                    layout.apply_churn(&sg, &plan, &assign);
                }
                // every other round: rescale through the same machinery
                if rng.chance(0.5) {
                    let new_k = 1 + rng.below_usize(8);
                    let plan = sg.rescale_plan(k, new_k);
                    let assign = sg.assignment(new_k);
                    layout.apply_churn(&sg, &plan, &assign);
                    k = new_k;
                }
                let assign = sg.assignment(k);
                let fresh = PartitionLayout::build(&sg, &assign);
                assert_layouts_equal(&layout, &fresh);
                // the staged target is chunk-contiguous over the physical
                // id space, so ownership stays at ≤ k intervals
                assert!(
                    layout.total_ranges() <= k,
                    "k={k}: {} intervals resident after churn",
                    layout.total_ranges()
                );
            }
        });
    }

    #[test]
    #[should_panic(expected = "not wholly owned")]
    fn inconsistent_plan_is_rejected() {
        let g = erdos_renyi(40, 160, 9);
        let m = g.num_edges();
        let part = EdgePartition::from_cep(&Cep::new(m, 4));
        let mut layout = PartitionLayout::build(&g, &part);
        // claim partition 0 owns a range that actually belongs to 3
        let mut plan = crate::scaling::migration::MigrationPlan::default();
        plan.push_range(0, 1, (m as u64 - 5)..m as u64);
        layout.apply_plan(&g, &plan, &part);
    }
}
