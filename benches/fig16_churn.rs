//! Fig 16 (beyond the paper — §7 future work realized): streaming churn
//! cost vs churn rate.
//!
//! For each per-batch churn rate, a batch stream is ingested into a
//! [`egs::stream::StagedGraph`]: tombstone deletions, locality-aware
//! staged insertions, an executable O(k + batch) delta plan per batch,
//! and a GEO compaction whenever the 10% quality budget trips. The
//! comparison column is the naive alternative — a **full GEO reorder
//! after every batch** — which is what the static pipeline would have to
//! do to stay fresh.
//!
//! Expected shape: per-batch streaming cost stays orders of magnitude
//! below a full reorder, and the amortized compaction count grows
//! linearly with the churn rate while RF drift stays within the budget.

mod common;

use common::BenchLog;
use egs::engine::mirrors::PartitionLayout;
use egs::metrics::table::{f3, secs, Table};
use egs::ordering::geo::{self, GeoConfig};
use egs::stream::{quality, MutationBatch, StagedGraph};
use egs::util::rng::Rng;
use std::time::Instant;

fn main() {
    let g = common::dataset("pokec-s");
    let m = g.num_edges();
    let k = 16usize;
    let cfg = GeoConfig::default();
    let batches = common::scaled(20, 5) as u32;
    let mut log = BenchLog::new("fig16");

    // naive baseline: one full GEO pass over the graph — the per-batch
    // cost of keeping a static pipeline fresh under churn
    let t = Instant::now();
    let _ = geo::order(&g, &cfg);
    let naive_s = t.elapsed().as_secs_f64();

    let mut table = Table::new(
        &format!("Fig 16: churn ingest cost vs rate (|E|={m}, k={k}, {batches} batches)"),
        &[
            "rate/batch",
            "stream/batch",
            "naive/batch",
            "speedup",
            "plan ops avg",
            "compactions",
            "RF live",
            "RF fresh",
        ],
    );

    for rate in [0.001f64, 0.005, 0.01, 0.02, 0.05] {
        let inserts = (m as f64 * rate) as u32;
        let deletes = inserts / 3;
        let mut sg = StagedGraph::new(g.clone(), cfg);
        let mut rng = Rng::new(0xF16);
        let mut stream_s = 0.0f64;
        let mut plan_ops = 0usize;
        // interval-set layout maintained *incrementally* across every
        // batch (the engine's path), so the reported telemetry would
        // expose any fragmentation bug in apply_churn
        let mut layout = {
            let assign = sg.assignment(k);
            PartitionLayout::build(&sg, &assign)
        };
        for _ in 0..batches {
            let mut batch = MutationBatch::new();
            let p = sg.physical_edges() as u64;
            for _ in 0..deletes {
                batch.delete(rng.below(p));
            }
            let n = sg.num_vertices() as u64;
            for _ in 0..inserts {
                batch.insert(rng.below(n) as u32, rng.below(n) as u32);
            }
            let t = Instant::now();
            let (_, plan) = sg.apply_batch(&batch, k);
            plan_ops += plan.range_ops();
            let compacted = sg.needs_compaction();
            if compacted {
                sg.compact();
            }
            stream_s += t.elapsed().as_secs_f64();
            // outside the timed ingest path: keep the layout current
            let assign = sg.assignment(k);
            if compacted {
                layout = PartitionLayout::build(&sg, &assign);
            } else {
                layout.apply_churn(&sg, &plan, &assign);
            }
        }
        let per_batch = stream_s / batches as f64;
        let assign = sg.assignment(k);
        let rf_live = quality::live_replication_factor(&sg, &assign);
        let (layout_ranges, layout_bytes) =
            (layout.total_ranges() as u64, layout.metadata_bytes() as u64);
        // fresh repartition of the mutated graph (the quality baseline)
        let live = sg.as_graph();
        let fresh = geo::order(&live, &cfg).apply(&live);
        let rf_fresh = egs::partition::quality::replication_factor_chunked(
            &fresh,
            &egs::partition::cep::Cep::new(fresh.num_edges(), k),
        );
        table.row(vec![
            format!("{:.1}%", rate * 100.0),
            secs(per_batch),
            secs(naive_s),
            format!("{:.0}x", naive_s / per_batch.max(1e-9)),
            format!("{:.1}", plan_ops as f64 / batches as f64),
            sg.compactions().to_string(),
            f3(rf_live),
            f3(rf_fresh),
        ]);
        log.row_layout(
            &format!("rate={:.3}", rate),
            per_batch * 1e3,
            Some(rf_live),
            layout_ranges,
            layout_bytes,
        );
    }
    table.print();
    log.finish();
    println!(
        "expected: per-batch streaming cost << one full GEO reorder; \
         RF live tracks RF fresh within the 10% compaction budget"
    );
}
