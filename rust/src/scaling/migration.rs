//! Executable migration plans: the diff between two partitionings of the
//! same ordered edge list, expressed as **contiguous edge-id range moves**
//! `(src, dst, [start, end))` rather than per-edge lists.
//!
//! Ranges are the native currency of chunk-based scaling: rescaling a CEP
//! layout `k → k±x` shifts O(k + k') chunk boundaries, so the whole plan
//! is O(k) range moves regardless of |E| ([`MigrationPlan::between_ceps`]).
//! Scattered methods (hash/BVC) still diff per edge, with maximal runs
//! coalesced into ranges ([`MigrationPlan::diff`]). The coordinator prices
//! plans on the emulated network and the engine executes them as
//! incremental state transfer ([`crate::engine::Engine::apply_migration`]).

use crate::partition::cep::Cep;
use crate::partition::PartitionAssignment;
use crate::{EdgeId, PartitionId};
use std::ops::Range;

/// One planned transfer: the contiguous block of edge ids
/// `edges.start..edges.end` moves from partition `src` to partition `dst`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RangeMove {
    /// sending partition (owner under the old layout)
    pub src: PartitionId,
    /// receiving partition (owner under the new layout)
    pub dst: PartitionId,
    /// half-open edge-id range being moved
    pub edges: Range<EdgeId>,
}

impl RangeMove {
    /// Number of edges in the move.
    pub fn len(&self) -> u64 {
        self.edges.end - self.edges.start
    }

    /// True when the range is empty (plans never contain such moves).
    pub fn is_empty(&self) -> bool {
        self.edges.start >= self.edges.end
    }
}

/// A full migration plan between two partitionings of the same edge set:
/// a list of non-overlapping [`RangeMove`]s covering exactly the edges
/// whose owner changed.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    /// planned moves, ascending by `edges.start`
    pub moves: Vec<RangeMove>,
}

impl MigrationPlan {
    /// Plan a CEP rescale `old → new` from chunk metadata alone — an
    /// O(k + k') sweep over the merged chunk-boundary set (Theorem 2's
    /// structure): between consecutive boundaries both owners are
    /// constant, so each differing segment is one range move. Never
    /// touches per-edge state.
    pub fn between_ceps(old: &Cep, new: &Cep) -> MigrationPlan {
        assert_eq!(old.num_edges(), new.num_edges(), "edge sets differ");
        let m = old.num_edges();
        let mut plan = MigrationPlan::default();
        if m == 0 {
            return plan;
        }
        let mut cuts: Vec<u64> = Vec::with_capacity(old.k() + new.k() + 2);
        for p in 0..=old.k() as u64 {
            cuts.push(crate::partition::cep::chunk_start(m, old.k() as u64, p));
        }
        for p in 0..=new.k() as u64 {
            cuts.push(crate::partition::cep::chunk_start(m, new.k() as u64, p));
        }
        cuts.sort_unstable();
        cuts.dedup();
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1].min(m));
            if lo >= m {
                break;
            }
            let (src, dst) = (old.partition_of(lo), new.partition_of(lo));
            if src != dst {
                plan.push_range(src, dst, lo..hi);
            }
        }
        plan
    }

    /// Plan a **boundary shift** between two monotone boundary arrays
    /// over the same edge list (`bounds[0] == 0`, `bounds[k] == m`,
    /// non-decreasing — the [`crate::partition::WeightedCepView`]
    /// representation). Same merged-cut sweep as [`Self::between_ceps`]:
    /// between consecutive cuts both owners are constant, so the plan is
    /// O(k + k') range moves with zero per-edge work. For equal `k` the
    /// plan has at most `2(k−1)` moves: the cut set holds ≤ 2k distinct
    /// values, and when it is maximal the first window is owned by
    /// partition 0 on both sides.
    pub fn between_boundaries(old_bounds: &[u64], new_bounds: &[u64]) -> MigrationPlan {
        assert!(
            old_bounds.len() >= 2 && new_bounds.len() >= 2,
            "bounds need k+1 >= 2 entries"
        );
        let m = *old_bounds.last().unwrap();
        assert_eq!(m, *new_bounds.last().unwrap(), "edge sets differ");
        let mut plan = MigrationPlan::default();
        if m == 0 {
            return plan;
        }
        // owner = largest p with bounds[p] <= i (ties resolve past empty
        // partitions, matching WeightedCepView::partition_of)
        let owner = |bounds: &[u64], i: u64| -> PartitionId {
            (bounds.partition_point(|&b| b <= i) - 1) as PartitionId
        };
        let mut cuts: Vec<u64> = Vec::with_capacity(old_bounds.len() + new_bounds.len());
        cuts.extend_from_slice(old_bounds);
        cuts.extend_from_slice(new_bounds);
        cuts.sort_unstable();
        cuts.dedup();
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1].min(m));
            if lo >= m {
                break;
            }
            let (src, dst) = (owner(old_bounds, lo), owner(new_bounds, lo));
            if src != dst {
                plan.push_range(src, dst, lo..hi);
            }
        }
        plan
    }

    /// Diff two arbitrary assignments — O(m), coalescing maximal runs of
    /// consecutive edge ids with the same `(src, dst)` pair into single
    /// range moves.
    pub fn diff<A, B>(old: &A, new: &B) -> MigrationPlan
    where
        A: PartitionAssignment + ?Sized,
        B: PartitionAssignment + ?Sized,
    {
        assert_eq!(old.num_edges(), new.num_edges(), "edge sets differ");
        let mut plan = MigrationPlan::default();
        for i in 0..old.num_edges() {
            let (src, dst) = (old.partition_of(i), new.partition_of(i));
            if src != dst {
                plan.push_edge(src, dst, i);
            }
        }
        plan
    }

    /// Append edge `i` to the plan, extending the last move when it is the
    /// contiguous continuation of the same `(src, dst)` pair. Edges must be
    /// pushed in ascending id order.
    pub fn push_edge(&mut self, src: PartitionId, dst: PartitionId, i: EdgeId) {
        if let Some(last) = self.moves.last_mut() {
            if last.src == src && last.dst == dst && last.edges.end == i {
                last.edges.end = i + 1;
                return;
            }
        }
        self.moves.push(RangeMove { src, dst, edges: i..i + 1 });
    }

    /// Append a whole range move (must not be empty and must start at or
    /// after the end of the previous move).
    pub fn push_range(&mut self, src: PartitionId, dst: PartitionId, edges: Range<EdgeId>) {
        debug_assert!(edges.start < edges.end, "empty range move");
        debug_assert!(
            self.moves.last().map(|l| l.edges.end <= edges.start).unwrap_or(true),
            "range moves must be pushed in ascending order"
        );
        if let Some(last) = self.moves.last_mut() {
            if last.src == src && last.dst == dst && last.edges.end == edges.start {
                last.edges.end = edges.end;
                return;
            }
        }
        self.moves.push(RangeMove { src, dst, edges });
    }

    /// Total migrated edges.
    pub fn migrated_edges(&self) -> u64 {
        self.moves.iter().map(|t| t.len()).sum()
    }

    /// Number of range moves (the plan's *size* — O(k) for CEP rescales,
    /// up to O(m) for scattered methods).
    pub fn num_moves(&self) -> usize {
        self.moves.len()
    }

    /// True when nothing moves.
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Bytes on the wire for a given per-edge payload: 8 B of structure
    /// (two u32 endpoints) plus `value_bytes` of application state
    /// (Fig 14 sweeps 0–32 B).
    pub fn bytes(&self, value_bytes: u64) -> u64 {
        self.migrated_edges() * (8 + value_bytes)
    }

    /// Per-sender byte volumes (the network emulator serializes per link).
    pub fn per_sender_bytes(&self, value_bytes: u64, k: usize) -> Vec<u64> {
        let mut out = vec![0u64; k];
        for t in &self.moves {
            out[t.src as usize] += t.len() * (8 + value_bytes);
        }
        out
    }

    /// Coalesce adjacent same-destination moves into single contiguous
    /// **destination spans** `(dst, range)` — the insert side of plan
    /// execution. [`Self::diff`] (and [`crate::stream::plan::ChurnPlan::derive`])
    /// already merge consecutive edges with an identical `(src, dst)`
    /// pair; this second pass additionally merges neighbouring moves that
    /// share only the destination (e.g. `0→2, 5..8` followed by
    /// `1→2, 8..11` lands at partition 2 as one `5..11` splice), so the
    /// layout executes one interval edit per destination span instead of
    /// one per move. Moves are walked in plan order (ascending by
    /// `edges.start`); degenerate `src == dst` or empty moves are skipped.
    pub fn dst_spans(&self) -> Vec<(PartitionId, Range<EdgeId>)> {
        let mut out: Vec<(PartitionId, Range<EdgeId>)> = Vec::new();
        for mv in &self.moves {
            if mv.src == mv.dst || mv.is_empty() {
                continue;
            }
            match out.last_mut() {
                Some((d, r)) if *d == mv.dst && r.end == mv.edges.start => r.end = mv.edges.end,
                _ => out.push((mv.dst, mv.edges.clone())),
            }
        }
        out
    }

    /// Partitions that send or receive edges under this plan, deduplicated
    /// and ascending.
    pub fn touched_partitions(&self) -> Vec<PartitionId> {
        let mut ps: Vec<PartitionId> =
            self.moves.iter().flat_map(|t| [t.src, t.dst]).collect();
        ps.sort_unstable();
        ps.dedup();
        ps
    }

    /// Check exactness against the two assignments: moves are non-empty,
    /// non-overlapping, in-bounds, `src ≠ dst`, every planned edge really
    /// changes owner `src → dst`, and the union of the ranges is exactly
    /// the set of edges whose owner differs.
    pub fn validate<A, B>(&self, old: &A, new: &B) -> bool
    where
        A: PartitionAssignment + ?Sized,
        B: PartitionAssignment + ?Sized,
    {
        let m = old.num_edges();
        if new.num_edges() != m {
            return false;
        }
        let mut sorted: Vec<&RangeMove> = self.moves.iter().collect();
        sorted.sort_by_key(|t| t.edges.start);
        let mut prev_end = 0u64;
        let mut planned = 0u64;
        for t in sorted {
            if t.is_empty() || t.src == t.dst || t.edges.start < prev_end || t.edges.end > m {
                return false;
            }
            prev_end = t.edges.end;
            planned += t.len();
            for i in t.edges.clone() {
                if old.partition_of(i) != t.src || new.partition_of(i) != t.dst {
                    return false;
                }
            }
        }
        let changed =
            (0..m).filter(|&i| old.partition_of(i) != new.partition_of(i)).count() as u64;
        planned == changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{CepView, EdgePartition};
    use crate::util::proptest::check;

    #[test]
    fn diff_of_identical_is_empty() {
        let p = EdgePartition::new(3, vec![0, 1, 2, 0, 1]);
        let plan = MigrationPlan::diff(&p, &p);
        assert_eq!(plan.migrated_edges(), 0);
        assert!(plan.is_empty());
        assert!(plan.validate(&p, &p));
    }

    #[test]
    fn diff_tracks_moves() {
        let old = EdgePartition::new(2, vec![0, 0, 1, 1]);
        let new = EdgePartition::new(2, vec![0, 1, 1, 0]);
        let plan = MigrationPlan::diff(&old, &new);
        assert_eq!(plan.migrated_edges(), 2);
        assert_eq!(plan.num_moves(), 2);
        assert!(plan.validate(&old, &new));
        assert_eq!(plan.bytes(0), 16);
        assert_eq!(plan.bytes(8), 32);
    }

    #[test]
    fn diff_coalesces_runs_into_ranges() {
        let old = EdgePartition::new(2, vec![0, 0, 1, 1]);
        let new = EdgePartition::new(2, vec![1, 1, 0, 0]);
        let plan = MigrationPlan::diff(&old, &new);
        assert_eq!(plan.migrated_edges(), 4);
        assert_eq!(plan.num_moves(), 2, "consecutive same-pair edges must coalesce");
        assert_eq!(plan.moves[0], RangeMove { src: 0, dst: 1, edges: 0..2 });
        assert_eq!(plan.moves[1], RangeMove { src: 1, dst: 0, edges: 2..4 });
        assert_eq!(plan.touched_partitions(), vec![0, 1]);
    }

    /// Adjacent moves that share only the destination coalesce into one
    /// span on the insert side, while distinct destinations stay apart.
    #[test]
    fn dst_spans_coalesce_adjacent_same_destination_moves() {
        // ids 0..2 move 0→2, ids 2..4 move 1→2 (adjacent, same dst),
        // ids 4..5 move 1→0 (different dst)
        let old = EdgePartition::new(3, vec![0, 0, 1, 1, 1]);
        let new = EdgePartition::new(3, vec![2, 2, 2, 2, 0]);
        let plan = MigrationPlan::diff(&old, &new);
        assert_eq!(plan.num_moves(), 3, "diff keeps per-source moves");
        let spans = plan.dst_spans();
        assert_eq!(spans, vec![(2, 0..4), (0, 4..5)]);
        assert_eq!(
            spans.iter().map(|(_, r)| r.end - r.start).sum::<u64>(),
            plan.migrated_edges()
        );
    }

    #[test]
    fn plan_validates_for_random_cep_rescale() {
        check(0x9147, 24, |rng| {
            let m = 1000 + rng.below_usize(5000);
            let k0 = 2 + rng.below_usize(20);
            let k1 = 2 + rng.below_usize(20);
            let old = EdgePartition::from_cep(&Cep::new(m, k0));
            let new = EdgePartition::from_cep(&Cep::new(m, k1));
            let plan = MigrationPlan::diff(&old, &new);
            assert!(plan.validate(&old, &new));
            let per = plan.per_sender_bytes(4, k0.max(k1));
            assert_eq!(per.iter().sum::<u64>(), plan.bytes(4));
        });
    }

    /// Satellite property: the plan is **exact** — the union of its ranges
    /// equals the set of edges whose owner differs between the old and new
    /// `Cep` layouts (differential against the naive O(m) comparison), and
    /// its size is O(k + k'), independent of m.
    #[test]
    fn between_ceps_plan_is_exact_and_range_sized() {
        check(0xE4AC7, 48, |rng| {
            let m = 100 + rng.below_usize(5000);
            let k0 = 1 + rng.below_usize(40);
            let k1 = 1 + rng.below_usize(40);
            let a = Cep::new(m, k0);
            let b = Cep::new(m, k1);
            let plan = MigrationPlan::between_ceps(&a, &b);
            assert!(
                plan.num_moves() <= k0 + k1 + 1,
                "m={m} {k0}->{k1}: plan has {} moves",
                plan.num_moves()
            );
            let mut in_plan = vec![false; m];
            for t in &plan.moves {
                assert_ne!(t.src, t.dst, "m={m} {k0}->{k1}");
                for i in t.edges.clone() {
                    assert!(!in_plan[i as usize], "overlapping move at edge {i}");
                    in_plan[i as usize] = true;
                    assert_eq!(a.partition_of(i), t.src, "m={m} {k0}->{k1} i={i}");
                    assert_eq!(b.partition_of(i), t.dst, "m={m} {k0}->{k1} i={i}");
                }
            }
            for (i, planned) in in_plan.iter().enumerate() {
                let moved = a.partition_of(i as u64) != b.partition_of(i as u64);
                assert_eq!(*planned, moved, "m={m} {k0}->{k1} i={i}");
            }
            let (va, vb) = (CepView::new(a), CepView::new(b));
            assert!(plan.validate(&va, &vb));
        });
    }

    /// Random monotone boundary arrays (same m): the boundary-shift plan's
    /// move-range union equals the naive per-edge changed-owner diff, and
    /// same-k shifts stay within the 2(k−1) move bound.
    #[test]
    fn between_boundaries_matches_per_edge_diff() {
        use crate::partition::WeightedCepView;
        check(0xB0B5, 48, |rng| {
            let m = 1 + rng.below(3000);
            let k = 2 + rng.below_usize(24);
            let mk_bounds = |rng: &mut crate::util::rng::Rng| {
                let mut cuts: Vec<u64> = (0..k - 1).map(|_| rng.below(m + 1)).collect();
                cuts.sort_unstable();
                let mut b = vec![0u64];
                b.extend(cuts);
                b.push(m);
                b
            };
            let old_b = mk_bounds(rng);
            let new_b = mk_bounds(rng);
            let plan = MigrationPlan::between_boundaries(&old_b, &new_b);
            assert!(
                plan.num_moves() <= 2 * (k - 1),
                "k={k} plan has {} moves\nold={old_b:?}\nnew={new_b:?}",
                plan.num_moves()
            );
            let old_v = WeightedCepView::from_bounds(old_b.clone());
            let new_v = WeightedCepView::from_bounds(new_b.clone());
            assert!(plan.validate(&old_v, &new_v), "old={old_b:?} new={new_b:?}");
            let slow = MigrationPlan::diff(&old_v, &new_v);
            assert_eq!(slow.moves, plan.moves, "old={old_b:?} new={new_b:?}");
        });
    }

    #[test]
    fn between_boundaries_agrees_with_between_ceps_on_uniform_grids() {
        use crate::partition::weighted::uniform_bounds;
        check(0xB0C2, 32, |rng| {
            let m = 1 + rng.below(4000);
            let k0 = 1 + rng.below_usize(30);
            let k1 = 1 + rng.below_usize(30);
            let a = Cep::new(m as usize, k0);
            let b = Cep::new(m as usize, k1);
            let via_cep = MigrationPlan::between_ceps(&a, &b);
            let via_bounds = MigrationPlan::between_boundaries(
                &uniform_bounds(m, k0),
                &uniform_bounds(m, k1),
            );
            assert_eq!(via_cep.moves, via_bounds.moves, "m={m} {k0}->{k1}");
        });
    }

    #[test]
    fn between_ceps_matches_per_edge_diff() {
        check(0xD1FF, 32, |rng| {
            let m = 50 + rng.below_usize(3000);
            let k0 = 1 + rng.below_usize(30);
            let k1 = 1 + rng.below_usize(30);
            let a = Cep::new(m, k0);
            let b = Cep::new(m, k1);
            let fast = MigrationPlan::between_ceps(&a, &b);
            let slow = MigrationPlan::diff(
                &EdgePartition::from_cep(&a),
                &EdgePartition::from_cep(&b),
            );
            assert_eq!(fast.moves, slow.moves, "m={m} {k0}->{k1}");
        });
    }
}
