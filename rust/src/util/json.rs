//! Hand-rolled JSON support for the artifact manifest (no `serde` in the
//! vendored crate set). This is a deliberately small parser: objects,
//! arrays, strings (no escapes beyond \" \\ \/ \n \t), numbers, booleans
//! and null — exactly what `python/compile/aot.py` emits.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (stored as f64; manifest integers are small)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (ordered map for deterministic round-trips)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing junk at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            None => Err("eof".into()),
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.num(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn num(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        while let Some(&c) = self.b.get(self.i) {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = *self.b.get(self.i).ok_or("eof in escape")?;
                    self.i += 1;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        _ => return Err(format!("unsupported escape \\{}", esc as char)),
                    });
                }
                _ => out.push(c as char),
            }
        }
        Err("unterminated string".into())
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut m = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn arr(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut v = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "version": 1,
          "apps": ["pagerank", "sssp"],
          "variants": [
            {"name": "v4096_e32768", "vcap": 4096, "ecap": 32768,
             "files": {"pagerank": "pagerank_v4096_e32768.hlo.txt"}}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let variants = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants[0].get("vcap").unwrap().as_usize(), Some(4096));
        assert_eq!(
            variants[0].get("files").unwrap().get("pagerank").unwrap().as_str(),
            Some("pagerank_v4096_e32768.hlo.txt")
        );
    }

    #[test]
    fn rejects_trailing_junk() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
