//! Evaluators for the graph-edge-ordering objective:
//! Eq. (1) of Def. 4 (full ordering) and Eq. (7) (partial ordering, used
//! by the baseline greedy Algorithm 3), plus the `S_k` splitting-point
//! indicator of Def. 5.

use crate::graph::Graph;
use crate::partition::cep::{chunk_range, chunk_width, id2p};

/// Eq. (1): `(1/|V|) Σ_{k=k_min}^{k_max} Σ_p |V(chunk(k,p))|` for a graph
/// whose edge list is already in φ order. O((k_max−k_min)·|E|) with an
/// epoch-stamped vertex marker (no per-chunk allocation).
pub fn eval_eq1(g_ordered: &Graph, k_min: usize, k_max: usize) -> f64 {
    assert!(k_min >= 1 && k_max >= k_min);
    let n = g_ordered.num_vertices();
    let m = g_ordered.num_edges() as u64;
    if n == 0 || m == 0 {
        return 0.0;
    }
    let edges = g_ordered.edges();
    let mut stamp = vec![0u32; n];
    let mut epoch = 0u32;
    let mut total: u64 = 0;
    for k in k_min..=k_max {
        for p in 0..k as u64 {
            epoch += 1;
            let mut cnt = 0u64;
            for i in chunk_range(m, k as u64, p) {
                let e = edges[i as usize];
                if stamp[e.u as usize] != epoch {
                    stamp[e.u as usize] = epoch;
                    cnt += 1;
                }
                if stamp[e.v as usize] != epoch {
                    stamp[e.v as usize] = epoch;
                    cnt += 1;
                }
            }
            total += cnt;
        }
    }
    total as f64 / n as f64
}

/// `S_k(i)` (Def. 5): 1 iff `i` is the last edge of a chunk of `k`
/// partitions (including `i = m−1`).
#[inline]
pub fn is_split_point(m: u64, k: u64, i: u64) -> bool {
    i + 1 == m || id2p(m, k, i) != id2p(m, k, i + 1)
}

/// Eq. (7): the objective extended to a *partial* ordered edge list `X`
/// (a prefix of a future full ordering over a graph with `m_total` edges).
/// `x_edges` are the ordered edges so far as `(u, v)` pairs. Returns the
/// un-normalized sum (divide by |V| for the paper's value).
///
/// Chunks are clipped per Def. 5's extension:
/// `X_ch(i−w+1, w)` = edges `[max(0, i−w+1), min(i, |X|−1)]`, empty when
/// `|X| ≤ i−w+1`.
pub fn eval_partial_eq7(
    n: usize,
    x_edges: &[(u32, u32)],
    m_total: u64,
    k_min: usize,
    k_max: usize,
) -> u64 {
    let xlen = x_edges.len() as u64;
    if xlen == 0 {
        return 0;
    }
    let mut stamp = vec![0u32; n];
    let mut epoch = 0u32;
    let mut total = 0u64;
    for k in k_min as u64..=k_max as u64 {
        // iterate split points i (ends of chunks); the sum over all i of
        // f_k(X, i, w) has non-zero terms only at split points
        for p in 0..k {
            let r = chunk_range(m_total, k, p);
            if r.is_empty() {
                continue;
            }
            let i = r.end - 1; // the split index for partition p
            let w = chunk_width(m_total, k, p);
            // clipped chunk of X: [i-w+1, i] ∩ [0, xlen-1]
            let lo = i + 1 - w; // = r.start
            if xlen <= lo {
                continue; // empty per the Def. 5 extension
            }
            let hi = i.min(xlen - 1);
            epoch += 1;
            let mut cnt = 0u64;
            for j in lo..=hi {
                let (u, v) = x_edges[j as usize];
                if stamp[u as usize] != epoch {
                    stamp[u as usize] = epoch;
                    cnt += 1;
                }
                if stamp[v as usize] != epoch {
                    stamp[v as usize] = epoch;
                    cnt += 1;
                }
            }
            total += cnt;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::erdos_renyi;

    #[test]
    fn eq1_on_path_graph() {
        // path 0-1-2-3-4: edges in order. k=2 → chunks {01,12},{23,34}
        // |V(c0)|=3, |V(c1)|=3 → (3+3)/5 = 1.2
        let g = GraphBuilder::new().edge(0, 1).edge(1, 2).edge(2, 3).edge(3, 4).build();
        let v = eval_eq1(&g, 2, 2);
        assert!((v - 6.0 / 5.0).abs() < 1e-12, "{v}");
    }

    #[test]
    fn eq1_grows_with_scattered_order() {
        // same path but interleaved edge order has more replicas
        let good = GraphBuilder::new().edge(0, 1).edge(1, 2).edge(2, 3).edge(3, 4).build();
        let bad = GraphBuilder::new().edge(0, 1).edge(2, 3).edge(1, 2).edge(3, 4).build();
        assert!(eval_eq1(&bad, 2, 2) > eval_eq1(&good, 2, 2));
    }

    #[test]
    fn split_points_count_equals_k() {
        for (m, k) in [(14u64, 4u64), (100, 7), (9, 3), (5, 9)] {
            let nonempty = (0..k).filter(|&p| chunk_width(m, k, p) > 0).count();
            let splits = (0..m).filter(|&i| is_split_point(m, k, i)).count();
            assert_eq!(splits, nonempty, "m={m} k={k}");
        }
    }

    #[test]
    fn partial_eq7_equals_eq1_on_complete_ordering() {
        // Lemma 1: Def. 4 ≡ Def. 5; with X = E the partial evaluator must
        // reproduce eval_eq1 exactly.
        let g = erdos_renyi(40, 120, 5);
        let x: Vec<(u32, u32)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
        let m = g.num_edges() as u64;
        for (kmin, kmax) in [(2usize, 2usize), (2, 5), (3, 8)] {
            let full = eval_eq1(&g, kmin, kmax);
            let partial = eval_partial_eq7(g.num_vertices(), &x, m, kmin, kmax);
            let normalized = partial as f64 / g.num_vertices() as f64;
            assert!((full - normalized).abs() < 1e-9, "kmin={kmin} kmax={kmax}");
        }
    }

    #[test]
    fn partial_eq7_monotone_in_prefix() {
        let g = erdos_renyi(30, 90, 6);
        let x: Vec<(u32, u32)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
        let m = g.num_edges() as u64;
        let mut prev = 0;
        for len in [10usize, 30, 60, 90] {
            let v = eval_partial_eq7(g.num_vertices(), &x[..len], m, 2, 4);
            assert!(v >= prev, "objective should not shrink as X grows");
            prev = v;
        }
    }
}
