//! Small shared utilities: deterministic RNG, CLI parsing, randomized
//! property-test harness, JSON scanning (the vendored crate set has no
//! `rand`, `clap`, `proptest` or `serde` — see DESIGN.md §3).

pub mod args;
pub mod json;
pub mod proptest;
pub mod rng;
