//! **DEG** — simple degree sorting (Table 5): vertices in descending
//! degree, ties by vertex id.

use super::VertexOrdering;
use crate::graph::Graph;
use crate::VertexId;

/// Sort vertices by descending degree.
pub fn order(g: &Graph) -> VertexOrdering {
    let mut perm: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    perm.sort_by_key(|&v| (std::cmp::Reverse(g.degree(v)), v));
    VertexOrdering::new(perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn descending_degree() {
        // star around 0 plus pendant chain
        let g = GraphBuilder::new().edge(0, 1).edge(0, 2).edge(0, 3).edge(3, 4).build();
        let o = order(&g);
        assert_eq!(o.as_slice()[0], 0); // degree 3
        assert_eq!(o.as_slice()[1], 3); // degree 2
    }
}
