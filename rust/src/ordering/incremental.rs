//! Incremental ordering maintenance for *dynamic graphs* — the paper's
//! first §7 future-work item.
//!
//! New edges are appended to a staging tail (keeping CEP's O(1) slicing
//! valid over `base + staging`); staged edges have no locality guarantee,
//! so quality decays as the staging fraction grows. `needs_reorder`
//! signals when the decay budget is spent and `reorder` folds everything
//! back through a fresh GEO pass — amortizing the expensive preprocessing
//! over many cheap insertions.
//!
//! This is the insertion-only precursor of the full streaming substrate
//! ([`crate::stream::StagedGraph`]), which adds deletions (tombstones),
//! locality-aware staging and executable delta plans.

use super::geo::{self, GeoConfig};
use crate::graph::builder::GraphBuilder;
use crate::graph::{Edge, Graph};
use crate::{EdgeId, VertexId};

/// Ordered edge list under insertions.
pub struct IncrementalOrder {
    /// GEO-ordered graph (base + folded staging)
    ordered: Graph,
    /// staged insertions since the last reorder
    staging: Vec<Edge>,
    /// reorder when staging exceeds this fraction of the base (default 10%)
    pub staging_budget: f64,
    cfg: GeoConfig,
    reorders: u32,
    /// permutation of the most recent GEO pass: `perm[new_position] =
    /// old_edge_id` in the edge list that pass consumed
    perm: Vec<EdgeId>,
}

impl IncrementalOrder {
    /// Start from a graph, GEO-ordering it once. Takes ownership so the
    /// caller's copy is released as soon as the ordered base is built —
    /// only one O(m) graph is ever retained (the previous borrowed API
    /// kept the caller's graph *and* the ordered copy alive).
    pub fn new(g: Graph, cfg: GeoConfig) -> IncrementalOrder {
        let perm = geo::order(&g, &cfg).into_perm();
        let ordered = g.permute_edges(&perm);
        drop(g);
        IncrementalOrder {
            ordered,
            staging: Vec::new(),
            staging_budget: 0.10,
            cfg,
            reorders: 0,
            perm,
        }
    }

    /// Total edges (base + staged).
    pub fn num_edges(&self) -> usize {
        self.ordered.num_edges() + self.staging.len()
    }

    /// Completed full reorders.
    pub fn reorders(&self) -> u32 {
        self.reorders
    }

    /// Staged fraction of the total.
    pub fn staging_fraction(&self) -> f64 {
        self.staging.len() as f64 / self.num_edges().max(1) as f64
    }

    /// Append a new edge (id space may grow).
    pub fn insert(&mut self, u: VertexId, v: VertexId) {
        self.staging.push(Edge::new(u, v));
    }

    /// True once the staging tail exceeds the budget.
    pub fn needs_reorder(&self) -> bool {
        self.staging_fraction() > self.staging_budget
    }

    /// The current ordered edge list: base order then staging tail. CEP
    /// can slice this directly (`Cep::new(self.num_edges(), k)`).
    pub fn edges(&self) -> Vec<Edge> {
        let mut out: Vec<Edge> = self.ordered.edges().iter().copied().collect();
        out.extend(self.staging.iter().copied());
        out
    }

    /// The ordered base graph (staging excluded).
    pub fn ordered(&self) -> &Graph {
        &self.ordered
    }

    /// Permutation of the most recent GEO pass (`perm[new_position] =
    /// old_edge_id` in the list that pass consumed) — what a snapshot
    /// persists next to the ordered edge list so the ordering can be
    /// re-derived or audited without re-running GEO.
    pub fn permutation(&self) -> &[EdgeId] {
        &self.perm
    }

    /// Fold the staging tail back in with a fresh GEO pass.
    pub fn reorder(&mut self) {
        let mut b = GraphBuilder::new();
        for e in self.ordered.edges().iter() {
            b.push(e.u, e.v);
        }
        for e in self.staging.drain(..) {
            b.push(e.u, e.v);
        }
        let g = b.build();
        self.perm = geo::order(&g, &self.cfg).into_perm();
        self.ordered = g.permute_edges(&self.perm);
        self.reorders += 1;
    }

    /// Materialize the current state as a graph in list order (for quality
    /// evaluation).
    pub fn as_graph(&self) -> Graph {
        let edges = self.edges();
        let n = edges
            .iter()
            .map(|e| e.u.max(e.v) as usize + 1)
            .max()
            .unwrap_or(0);
        let el = crate::graph::EdgeList::from_vec(edges);
        let csr = crate::graph::Csr::build(n, &el);
        Graph::from_parts(el, csr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::partition::cep::Cep;
    use crate::partition::quality::replication_factor_chunked;
    use crate::util::rng::Rng;

    fn geo_cfg() -> GeoConfig {
        GeoConfig { k_min: 2, k_max: 16, ..Default::default() }
    }

    #[test]
    fn insertions_then_reorder_restores_quality() {
        let g = erdos_renyi(400, 3000, 1);
        let mut inc = IncrementalOrder::new(g, geo_cfg());
        let rf_initial =
            replication_factor_chunked(&inc.as_graph(), &Cep::new(inc.num_edges(), 8));

        // stage 15% random new edges
        let mut rng = Rng::new(2);
        while inc.staging_fraction() < 0.15 {
            inc.insert(rng.below(400) as u32, rng.below(400) as u32);
        }
        assert!(inc.needs_reorder());
        let rf_stale =
            replication_factor_chunked(&inc.as_graph(), &Cep::new(inc.num_edges(), 8));

        inc.reorder();
        assert_eq!(inc.reorders(), 1);
        assert!(!inc.needs_reorder());
        let rf_fresh =
            replication_factor_chunked(&inc.as_graph(), &Cep::new(inc.num_edges(), 8));
        // staged tail hurts quality; reorder recovers it
        assert!(rf_fresh <= rf_stale, "reorder must not hurt: {rf_fresh} vs {rf_stale}");
        assert!(rf_fresh < rf_initial * 1.2, "post-reorder near initial quality");
    }

    #[test]
    fn cep_remains_valid_over_staging() {
        let g = erdos_renyi(100, 600, 3);
        let mut inc = IncrementalOrder::new(g, geo_cfg());
        inc.insert(0, 99);
        inc.insert(5, 50);
        let c = Cep::new(inc.num_edges(), 4);
        let covered: u64 = (0..4u32).map(|p| c.width(p)).sum();
        assert_eq!(covered, inc.num_edges() as u64);
        assert_eq!(inc.edges().len(), inc.num_edges());
    }

    /// The exposed permutation reproduces the ordered base from the graph
    /// the last GEO pass consumed — exactly what a snapshot persists.
    #[test]
    fn permutation_reproduces_ordered_base() {
        let g = erdos_renyi(150, 900, 5);
        let reference = g.clone();
        let mut inc = IncrementalOrder::new(g, geo_cfg());
        assert_eq!(inc.permutation().len(), 900);
        let replayed = reference.permute_edges(inc.permutation());
        assert_eq!(replayed.edges().as_slice(), inc.ordered().edges().as_slice());

        // after a reorder the permutation refers to the pre-reorder list
        inc.insert(3, 77);
        inc.reorder();
        assert_eq!(inc.permutation().len(), inc.num_edges());
        let mut seen = vec![false; inc.num_edges()];
        for &e in inc.permutation() {
            assert!(!seen[e as usize], "duplicate id {e}");
            seen[e as usize] = true;
        }
    }
}
