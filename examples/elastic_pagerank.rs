//! **End-to-end driver** (DESIGN.md §5): the full three-layer stack on a
//! real workload.
//!
//! * loads a ~1M-edge synthetic social graph (LiveJournal stand-in),
//! * GEO-orders it once (the paper's preprocessing),
//! * boots the PowerLyra-like engine with the **XLA backend** — every
//!   per-partition superstep executes the AOT-compiled JAX/Pallas
//!   artifact through the PJRT CPU client (falling back to the native
//!   backend with a warning if `make artifacts` hasn't run),
//! * runs PageRank while a spot-instance trace provisions/preempts
//!   workers (k = 8 → … bounded in [6, 12]),
//! * rescales with CEP at every event through the plan pipeline: the O(1)
//!   `CepView` rescale derives an O(k) range-move `MigrationPlan`, the
//!   8 Gbps emulated network prices it, and `Engine::apply_migration`
//!   executes it in place (touched workers only — no full rebuild),
//! * logs per-epoch RF, repartition time, migrated edges, COM and the
//!   rank residual; prints the Table 7-style breakdown at the end.
//!
//! ```bash
//! make artifacts && cargo run --release --example elastic_pagerank
//! ```

use egs::coordinator::events::{SpotEvent, SpotTrace};
use egs::engine::{Combine, Engine};
use egs::graph::datasets;
use egs::metrics::table::{secs, Table};
use egs::ordering::geo::{self, GeoConfig};
use egs::partition::cep::Cep;
use egs::partition::{quality, CepView};
use egs::runtime::artifact::Manifest;
use egs::runtime::executor::XlaBackend;
use egs::runtime::native::NativeBackend;
use egs::runtime::{ComputeBackend, StepKind};
use egs::scaling::migration::MigrationPlan;
use egs::scaling::network::Network;
use std::time::Instant;

fn main() -> egs::Result<()> {
    let t_total = Instant::now();

    // ---------- load + preprocess ----------
    let t = Instant::now();
    let g = datasets::by_name("livej-s", 42).expect("dataset");
    println!(
        "[load]    livej-s: |V|={} |E|={} ({:?})",
        g.num_vertices(),
        g.num_edges(),
        t.elapsed()
    );
    let t = Instant::now();
    let ordered = geo::order(&g, &GeoConfig::default()).apply(&g);
    println!("[geo]     ordered {} edges in {:?}", ordered.num_edges(), t.elapsed());

    // ---------- backend: XLA artifacts if available ----------
    let xla = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => Some(XlaBackend::start(m)?),
        Err(e) => {
            eprintln!("[warn]    no artifacts ({e}); using native backend");
            None
        }
    };
    let make_backend = |xla: &Option<XlaBackend>| -> Box<dyn ComputeBackend> {
        match xla {
            Some(h) => Box::new(h.clone()),
            None => Box::new(NativeBackend::new()),
        }
    };
    println!(
        "[backend] {}",
        if xla.is_some() { "xla (PJRT CPU, AOT JAX/Pallas artifacts)" } else { "native" }
    );

    // ---------- initial deployment ----------
    let n = ordered.num_vertices();
    let m = ordered.num_edges();
    let k0 = 8usize;
    let t = Instant::now();
    // the engine consumes the O(1) chunk view directly — no per-edge
    // assignment vector exists anywhere on this path
    let mut view = CepView::new(Cep::new(m, k0));
    let mut engine = Engine::new(&ordered, &view, |_| make_backend(&xla))?;
    let init_s = t.elapsed().as_secs_f64();
    println!(
        "[init]    k={k0} engine up in {} (RF={:.3})",
        secs(init_s),
        quality::replication_factor_chunked(&ordered, view.cep())
    );

    // ---------- spot-market trace ----------
    let total_iters = 60u32;
    let trace = SpotTrace::generate(k0, 6, 12, total_iters, 6, 7);
    println!("[trace]   {} spot events over {total_iters} iterations", trace.events.len());

    // ---------- PageRank state ----------
    let aux: Vec<f32> = (0..n as u32)
        .map(|v| {
            let d = ordered.degree(v);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f32
            }
        })
        .collect();
    let mut ranks = vec![1.0f32 / n as f32; n];
    let active = vec![true; n];
    let base = (1.0 - 0.85) / n as f32;
    let net = Network::gbps(8.0);

    let mut app_s = 0.0;
    let mut scale_s = 0.0;
    let mut total_migrated = 0u64;
    let mut total_com = 0u64;
    let mut k = k0;
    let mut ev_idx = 0usize;
    let mut log = Table::new(
        "elastic_pagerank epoch log",
        &["iter", "event", "k", "RF", "plan", "moves", "migrated", "net-time", "residual"],
    );

    for it in 0..total_iters {
        // ---- spot event?
        let mut event_str = "-".to_string();
        let mut plan_t_str = "-".to_string();
        let mut moves_str = "-".to_string();
        let mut migrated_str = "-".to_string();
        let mut nettime = "-".to_string();
        if ev_idx < trace.events.len() && trace.events[ev_idx].0 == it {
            let (_, ev) = trace.events[ev_idx];
            ev_idx += 1;
            let new_k = match ev {
                SpotEvent::Provision => k + 1,
                SpotEvent::Preempt => k - 1,
            };
            event_str = format!("{ev:?}");
            // O(k) metadata: rescale the view and derive the range plan —
            // the paper's "essentially free" repartition, now executable
            let t = Instant::now();
            let new_view = CepView::new(view.cep().rescaled(new_k));
            let plan = MigrationPlan::between_ceps(view.cep(), new_view.cep());
            let plan_t = t.elapsed();
            let moved = plan.migrated_edges();
            let net_s = net.migration_time(&plan, k.max(new_k), 8);
            // execute the plan in place: only touched workers reload
            let t = Instant::now();
            engine.apply_migration(&ordered, &plan, &new_view, |_| make_backend(&xla))?;
            let apply_s = t.elapsed().as_secs_f64();
            scale_s += plan_t.as_secs_f64() + net_s + apply_s;
            total_migrated += moved;
            view = new_view;
            k = new_k;
            plan_t_str = format!("{plan_t:?}");
            moves_str = plan.num_moves().to_string();
            migrated_str = moved.to_string();
            nettime = secs(net_s);
        }

        // ---- one PageRank iteration
        let t = Instant::now();
        engine.comm.reset();
        let (contrib, _) =
            engine.superstep(StepKind::PageRank, Combine::Sum, &ranks, &aux, &active)?;
        let mut residual = 0.0f32;
        for v in 0..n {
            let next = base + 0.85 * contrib[v];
            residual += (next - ranks[v]).abs();
            ranks[v] = next;
        }
        total_com += engine.comm.total_bytes();
        app_s += t.elapsed().as_secs_f64();

        if event_str != "-" || it % 10 == 0 {
            log.row(vec![
                it.to_string(),
                event_str,
                k.to_string(),
                format!("{:.3}", quality::replication_factor_chunked(&ordered, view.cep())),
                plan_t_str,
                moves_str,
                migrated_str,
                nettime,
                format!("{residual:.2e}"),
            ]);
        }
    }
    log.print();

    // ---------- Table 7-style breakdown ----------
    let all = init_s + app_s + scale_s;
    let mut summary = Table::new(
        "breakdown (Table 7 analogue)",
        &["ALL", "INIT", "APP", "SCALE", "migrated", "COM MB", "final k"],
    );
    summary.row(vec![
        secs(all),
        secs(init_s),
        secs(app_s),
        secs(scale_s),
        total_migrated.to_string(),
        format!("{:.1}", total_com as f64 / 1e6),
        k.to_string(),
    ]);
    summary.print();
    let top: f32 = ranks.iter().cloned().fold(0.0, f32::max);
    println!(
        "done in {:?}; rank mass {:.6}, max rank {top:.3e}",
        t_total.elapsed(),
        ranks.iter().sum::<f32>()
    );
    if let Some(h) = xla {
        h.shutdown();
    }
    Ok(())
}
