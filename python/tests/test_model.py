"""L2 correctness: full model steps vs numpy oracles, including the
distributed-semantics properties the rust engine relies on (mass
conservation under scatter-add, min-combine monotonicity, padding
neutrality)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import edge_ops, ref
from tests.conftest import make_inputs


def _inputs(seed, nv, ne, pad=0.25):
    rng = np.random.default_rng(seed)
    return make_inputs(rng, nv, ne, pad)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nv=st.sampled_from([8, 77, 512]))
def test_pagerank_step_matches_ref(seed, nv):
    args = _inputs(seed, nv, edge_ops.EDGE_BLOCK)
    (got,) = model.pagerank_step(*args)
    want = ref.pagerank_step_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nv=st.sampled_from([8, 100, 999]))
def test_sssp_step_matches_ref(seed, nv):
    args = _inputs(seed, nv, edge_ops.EDGE_BLOCK)
    (got,) = model.sssp_step(*args)
    want = ref.sssp_step_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nv=st.sampled_from([8, 333]))
def test_wcc_step_matches_ref(seed, nv):
    args = _inputs(seed, nv, edge_ops.EDGE_BLOCK)
    (got,) = model.wcc_step(*args)
    want = ref.wcc_step_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pagerank_conserves_mass():
    # unmasked edges redistribute exactly state·aux of each source
    state, aux, src, dst, weight, mask = _inputs(3, 64, edge_ops.EDGE_BLOCK, pad=0.0)
    (out,) = model.pagerank_step(state, aux, src, dst, weight, mask)
    # each edge contributes state[src]*aux[src]; total mass equals the sum
    expected = float(np.sum(state[src] * aux[src]))
    np.testing.assert_allclose(float(np.sum(out)), expected, rtol=1e-4)


def test_min_steps_are_monotone():
    state, aux, src, dst, weight, mask = _inputs(5, 128, edge_ops.EDGE_BLOCK)
    (sssp,) = model.sssp_step(state, aux, src, dst, weight, mask)
    (wcc,) = model.wcc_step(state, aux, src, dst, weight, mask)
    assert np.all(np.asarray(sssp) <= state + 1e-7)
    assert np.all(np.asarray(wcc) <= state + 1e-7)


def test_padding_is_inert():
    # fully-masked trailing edges must not change results
    nv = 40
    ne = edge_ops.EDGE_BLOCK
    state, aux, src, dst, weight, mask = _inputs(11, nv, ne, pad=0.0)
    mask[ne // 2 :] = 0.0
    src[ne // 2 :] = 0
    dst[ne // 2 :] = 0
    (out,) = model.pagerank_step(state, aux, src, dst, weight, mask)
    half = ref.pr_messages_ref(state, aux, src[: ne // 2], mask[: ne // 2])
    want = np.zeros(nv, np.float32)
    np.add.at(want, dst[: ne // 2], np.asarray(half))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
