//! The elastic control plane — the L3 "coordination" layer: reacts to
//! infrastructure events (spot-instance provisioning/preemption), rescales
//! the partitioning with the configured method, migrates data through the
//! emulated network, and keeps the application running across epochs.
//!
//! One entry point: [`Controller::drive`] runs a [`Scenario`] under a
//! [`RunConfig`] on either substrate (batch or streaming/churn-capable)
//! and reports a [`RunReport`]. Between supersteps a configured
//! [`ScalingPolicy`] — the SLO-driven [`SloPolicy`] or the degenerate
//! [`ThresholdPolicy`] — senses the engine's logical meters
//! ([`SensorSnapshot`]), prices candidate actions through the selected
//! network model, and commits the winner; every decision is audited as a
//! [`DecisionRecord`] and is bit-identical at any `PALLAS_THREADS`.
//!
//! [`Scenario`]: crate::scaling::scenario::Scenario

pub mod config;
pub mod controller;
pub mod driver;
pub mod events;
pub mod policy;
pub mod provisioner;
pub mod state;

pub use config::{DriveMode, PolicyConfig, RunConfig};
pub use controller::{ChurnRecord, EventRecord, RebalanceRecord};
pub use driver::{Controller, RunReport};
pub use policy::{
    trigger, CandidatePricer, CandidateRecord, DecisionRecord, PricedAction, ScalingAction,
    ScalingPolicy, SensorSnapshot, SloConfig, SloPolicy, ThresholdPolicy,
};
