//! [`StagedAssignment`] — the streaming counterpart of
//! [`crate::partition::CepView`]: a [`PartitionAssignment`] over
//! `base + staging − tombstones` made of two integers of chunk metadata
//! plus a borrowed (budget-bounded) tombstone list. Every owner query is
//! O(1), liveness is O(log t), per-partition live sizes are O(k log t) —
//! no O(m) per-edge vector exists anywhere on the streaming path.

use crate::partition::cep::Cep;
use crate::partition::PartitionAssignment;
use crate::{EdgeId, PartitionId};
use std::ops::Range;

/// Chunk-based assignment over a staged physical edge-id space.
///
/// Physical ids `0..num_edges()` are sliced by a [`Cep`]; tombstoned ids
/// keep their *nominal* chunk owner (so plans and debug cross-checks can
/// reason about them) but are reported dead via
/// [`PartitionAssignment::is_live`], and every consumer that builds
/// per-partition state skips them. Live balance therefore deviates from
/// CEP's perfect physical balance by at most the tombstone fraction, which
/// the compaction budget bounds.
#[derive(Clone, Copy, Debug)]
pub struct StagedAssignment<'a> {
    cep: Cep,
    tombstones: &'a [EdgeId],
}

impl<'a> StagedAssignment<'a> {
    /// View `cep` with the given sorted tombstone list.
    pub fn new(cep: Cep, tombstones: &'a [EdgeId]) -> StagedAssignment<'a> {
        debug_assert!(tombstones.windows(2).all(|w| w[0] < w[1]), "tombstones unsorted");
        if let Some(&t) = tombstones.last() {
            debug_assert!(t < cep.num_edges(), "tombstone {t} beyond physical id space");
        }
        StagedAssignment { cep, tombstones }
    }

    /// The underlying chunk metadata.
    pub fn cep(&self) -> &Cep {
        &self.cep
    }

    /// The sorted tombstone list.
    pub fn tombstones(&self) -> &[EdgeId] {
        self.tombstones
    }

    /// Physical edge-id range of partition `p` — O(1). May contain dead
    /// ids; pair with [`Self::dead_slice`] to walk only live ids.
    pub fn range(&self, p: PartitionId) -> Range<EdgeId> {
        self.cep.range(p)
    }

    /// The tombstones falling inside `r`, as a sub-slice — O(log t).
    pub fn dead_slice(&self, r: Range<EdgeId>) -> &'a [EdgeId] {
        let a = self.tombstones.partition_point(|&d| d < r.start);
        let b = self.tombstones.partition_point(|&d| d < r.end);
        &self.tombstones[a..b]
    }

    /// Dead ids inside `r` — O(log t).
    pub fn dead_in(&self, r: Range<EdgeId>) -> u64 {
        self.dead_slice(r).len() as u64
    }

    /// Live edges per partition — O(k log t).
    pub fn live_sizes(&self) -> Vec<u64> {
        (0..self.k() as PartitionId)
            .map(|p| self.cep.width(p) - self.dead_in(self.cep.range(p)))
            .collect()
    }
}

impl PartitionAssignment for StagedAssignment<'_> {
    fn k(&self) -> usize {
        self.cep.k()
    }

    fn num_edges(&self) -> u64 {
        self.cep.num_edges()
    }

    #[inline]
    fn partition_of(&self, i: EdgeId) -> PartitionId {
        self.cep.partition_of(i)
    }

    #[inline]
    fn is_live(&self, i: EdgeId) -> bool {
        self.tombstones.binary_search(&i).is_err()
    }

    fn num_live_edges(&self) -> u64 {
        self.cep.num_edges() - self.tombstones.len() as u64
    }

    /// Live sizes — what balance metrics should price for a staged state.
    fn sizes(&self) -> Vec<u64> {
        self.live_sizes()
    }

    /// Physical chunk ranges (holes are dead ids; check
    /// [`PartitionAssignment::is_live`] when walking them).
    fn as_chunks(&self) -> Option<Vec<Range<EdgeId>>> {
        Some((0..self.k() as PartitionId).map(|p| self.cep.range(p)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn liveness_and_sizes_respect_tombstones() {
        let dead = vec![0u64, 5, 6, 13];
        let a = StagedAssignment::new(Cep::new(14, 4), &dead);
        // paper Fig 3 widths: 3,3,4,4 — dead: id0 (p0), 5 (p1), 6 (p2), 13 (p3)
        assert_eq!(a.live_sizes(), vec![2, 2, 3, 3]);
        assert_eq!(a.num_live_edges(), 10);
        assert_eq!(a.num_edges(), 14);
        assert!(!a.is_live(5));
        assert!(a.is_live(4));
        assert_eq!(a.dead_slice(3..7), &[5, 6]);
        assert_eq!(a.dead_in(0..14), 4);
    }

    #[test]
    fn no_tombstones_behaves_like_cep_view() {
        let a = StagedAssignment::new(Cep::new(137, 10), &[]);
        let v = crate::partition::CepView::new(Cep::new(137, 10));
        assert_eq!(a.sizes(), v.sizes());
        assert_eq!(a.as_chunks(), v.as_chunks());
        for i in 0..137u64 {
            assert_eq!(a.partition_of(i), v.partition_of(i));
            assert!(a.is_live(i));
        }
    }
}
