//! **Interval-set ownership metadata** — the O(ranges) substrate under
//! every per-partition edge-id set in the pipeline.
//!
//! Chunk-based edge partitioning makes each partition a union of a *few*
//! contiguous ranges of the ordered edge list, so materializing ownership
//! as a sorted `Vec<EdgeId>` (8 B/edge) wastes both memory and rescale
//! time: a range move would drain and re-splice O(m) ids. An
//! [`IdRangeSet`] stores the same set as a sorted, coalesced,
//! non-overlapping list of half-open ranges plus a cumulative-count index:
//!
//! * membership and rank are O(log r) binary searches,
//! * [`IdRangeSet::splice_out`] / [`IdRangeSet::splice_in`] execute a
//!   plan's range move as pure interval edits — an O(log r) search plus an
//!   O(r) `Vec` splice, never per-edge work,
//! * [`IdRangeSet::len`] is O(1) off the index; [`IdRangeSet::live_len`]
//!   masks a sorted tombstone list in O(r log t),
//! * consumers walk [`IdRangeSet::ranges`] (or the tombstone-masked
//!   [`IdRangeSet::live_ranges`]) and index the CSR / [`crate::graph::EdgeSource`]
//!   by range instead of touching individual ids.
//!
//! On a chunk-contiguous layout (CEP, streaming staged chunks) every
//! partition owns exactly one interval, so the whole
//! [`crate::engine::mirrors::PartitionLayout`] carries O(k) ownership
//! metadata instead of O(m) — the representation change that keeps
//! billion-edge rescales at O(k + moved ranges).
//!
//! Invariants (checked by `debug_assert` and the unit suite): ranges are
//! non-empty, strictly ascending, and *coalesced* — adjacent ranges merge,
//! so `ranges[i].end < ranges[i+1].start` always.

use crate::EdgeId;
use std::ops::Range;

/// A set of edge ids stored as sorted, coalesced, non-overlapping
/// half-open ranges with a cumulative-count index for O(log r) rank
/// queries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IdRangeSet {
    /// sorted, coalesced, non-empty, non-overlapping intervals
    ranges: Vec<Range<EdgeId>>,
    /// `prefix[i]` = ids contained in `ranges[..i]`; `prefix[ranges.len()]`
    /// is the total cardinality, so `len()` is O(1)
    prefix: Vec<u64>,
}

impl Default for IdRangeSet {
    fn default() -> Self {
        IdRangeSet::new()
    }
}

impl IdRangeSet {
    /// The empty set.
    pub fn new() -> IdRangeSet {
        IdRangeSet { ranges: Vec::new(), prefix: vec![0] }
    }

    /// A set owning exactly `r` (the chunk-contiguous fast path: one
    /// interval per partition, O(1)). An empty `r` yields the empty set.
    pub fn from_range(r: Range<EdgeId>) -> IdRangeSet {
        if r.start >= r.end {
            return IdRangeSet::new();
        }
        IdRangeSet { prefix: vec![0, r.end - r.start], ranges: vec![r] }
    }

    /// Build from strictly ascending ids, coalescing consecutive runs —
    /// O(n) time, O(runs) memory ([`Self::push_back`] per id; scattered
    /// assignments feed `push_back` directly during layout construction).
    pub fn from_sorted_ids<I: IntoIterator<Item = EdgeId>>(ids: I) -> IdRangeSet {
        let mut s = IdRangeSet::new();
        for id in ids {
            s.push_back(id);
        }
        s
    }

    /// Append `id`, which must lie beyond every contained id — O(1),
    /// coalescing with the last range when contiguous.
    pub fn push_back(&mut self, id: EdgeId) {
        if let Some(last) = self.ranges.last_mut() {
            assert!(id >= last.end, "push_back id {id} not beyond existing ranges");
            if id == last.end {
                last.end += 1;
                *self.prefix.last_mut().unwrap() += 1;
                return;
            }
        }
        let total = *self.prefix.last().unwrap();
        self.ranges.push(id..id + 1);
        self.prefix.push(total + 1);
    }

    /// Number of contained ids — O(1).
    pub fn len(&self) -> u64 {
        *self.prefix.last().unwrap()
    }

    /// True when no ids are contained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of intervals `r` — the metadata footprint.
    pub fn num_ranges(&self) -> usize {
        self.ranges.len()
    }

    /// The intervals, sorted ascending and coalesced. Consumers should
    /// walk these and index edge storage by range rather than flattening.
    pub fn ranges(&self) -> &[Range<EdgeId>] {
        &self.ranges
    }

    /// Flattened id iterator (ascending) — for tests and debug
    /// cross-checks; hot paths walk [`Self::ranges`] instead.
    pub fn iter(&self) -> impl Iterator<Item = EdgeId> + '_ {
        self.ranges.iter().flat_map(|r| r.clone())
    }

    /// Is `id` contained? O(log r).
    pub fn contains(&self, id: EdgeId) -> bool {
        let i = self.ranges.partition_point(|rg| rg.end <= id);
        i < self.ranges.len() && self.ranges[i].start <= id
    }

    /// Number of contained ids strictly below `id` — O(log r) off the
    /// cumulative index.
    pub fn rank(&self, id: EdgeId) -> u64 {
        let i = self.ranges.partition_point(|rg| rg.end <= id);
        let mut r = self.prefix[i];
        if i < self.ranges.len() && self.ranges[i].start < id {
            r += id - self.ranges[i].start;
        }
        r
    }

    /// Splice the contiguous range `r` in: an O(log r) locate plus one
    /// `Vec` splice, coalescing with a touching left/right neighbour.
    /// Panics when any id of `r` is already contained — ownership sets are
    /// disjoint, so an overlapping insert is a plan-execution bug.
    pub fn splice_in(&mut self, r: Range<EdgeId>) {
        assert!(r.start < r.end, "splice_in of empty range {}..{}", r.start, r.end);
        let i = self.ranges.partition_point(|rg| rg.end < r.start);
        let j = self.ranges.partition_point(|rg| rg.start <= r.end);
        let mut merged = r.clone();
        for rg in &self.ranges[i..j] {
            assert!(
                rg.end == r.start || rg.start == r.end,
                "splice_in range {}..{} overlaps owned range {}..{}",
                r.start,
                r.end,
                rg.start,
                rg.end
            );
            merged.start = merged.start.min(rg.start);
            merged.end = merged.end.max(rg.end);
        }
        self.ranges.splice(i..j, [merged]);
        self.reindex();
    }

    /// Splice the contiguous range `r` out: O(log r) locate plus one
    /// `Vec` edit, splitting the containing interval when `r` is interior.
    /// Panics when `r` is not wholly contained — the "plan range not
    /// wholly owned" guard of migration execution.
    pub fn splice_out(&mut self, r: Range<EdgeId>) {
        assert!(r.start < r.end, "splice_out of empty range {}..{}", r.start, r.end);
        let i = self.ranges.partition_point(|rg| rg.end <= r.start);
        assert!(
            i < self.ranges.len()
                && self.ranges[i].start <= r.start
                && r.end <= self.ranges[i].end,
            "range {}..{} not wholly owned by this set",
            r.start,
            r.end
        );
        let owned = self.ranges[i].clone();
        match (owned.start < r.start, r.end < owned.end) {
            (true, true) => {
                self.ranges[i].end = r.start;
                self.ranges.insert(i + 1, r.end..owned.end);
            }
            (true, false) => self.ranges[i].end = r.start,
            (false, true) => self.ranges[i].start = r.end,
            (false, false) => {
                self.ranges.remove(i);
            }
        }
        self.reindex();
    }

    /// Contained ids that are **not** in the sorted tombstone list `dead`
    /// — O(r log t), two binary searches per interval.
    pub fn live_len(&self, dead: &[EdgeId]) -> u64 {
        let mut live = self.len();
        for r in &self.ranges {
            let a = dead.partition_point(|&d| d < r.start);
            let b = dead.partition_point(|&d| d < r.end);
            live -= (b - a) as u64;
        }
        live
    }

    /// Tombstone-masked iteration: maximal live sub-ranges of every
    /// interval, skipping the ids in the sorted list `dead`.
    pub fn live_ranges<'a>(
        &'a self,
        dead: &'a [EdgeId],
    ) -> impl Iterator<Item = Range<EdgeId>> + 'a {
        self.ranges.iter().flat_map(move |r| live_subranges(r.clone(), dead))
    }

    /// Resident bytes of the interval metadata (the quantity the bench
    /// rows report as `layout_bytes`).
    pub fn metadata_bytes(&self) -> usize {
        self.ranges.capacity() * std::mem::size_of::<Range<EdgeId>>()
            + self.prefix.capacity() * std::mem::size_of::<u64>()
    }

    /// Remove every id.
    pub fn clear(&mut self) {
        self.ranges.clear();
        self.prefix.clear();
        self.prefix.push(0);
    }

    /// Rebuild the cumulative-count index — O(r), called after every
    /// structural edit (the edits themselves are already O(r) `Vec`
    /// splices, so this does not change the asymptotics).
    fn reindex(&mut self) {
        self.prefix.clear();
        self.prefix.push(0);
        let mut total = 0u64;
        for r in &self.ranges {
            debug_assert!(r.start < r.end, "empty interval survived an edit");
            total += r.end - r.start;
            self.prefix.push(total);
        }
        debug_assert!(
            self.ranges.windows(2).all(|w| w[0].end < w[1].start),
            "intervals not sorted/coalesced"
        );
    }
}

/// Maximal live sub-ranges of `r` after masking the sorted tombstone ids
/// in `dead` (ids outside `r` are ignored). Shared by the layout's local
/// table rebuilds and the streaming quality sweeps.
pub fn live_subranges(r: Range<EdgeId>, dead: &[EdgeId]) -> LiveSubranges<'_> {
    let di = dead.partition_point(|&d| d < r.start);
    LiveSubranges { cur: r.start, end: r.end, dead, di }
}

/// Iterator of [`live_subranges`].
pub struct LiveSubranges<'a> {
    cur: EdgeId,
    end: EdgeId,
    dead: &'a [EdgeId],
    di: usize,
}

impl Iterator for LiveSubranges<'_> {
    type Item = Range<EdgeId>;

    fn next(&mut self) -> Option<Range<EdgeId>> {
        // skip the (strictly ascending) dead ids at the cursor
        while self.cur < self.end
            && self.di < self.dead.len()
            && self.dead[self.di] == self.cur
        {
            self.di += 1;
            self.cur += 1;
        }
        if self.cur >= self.end {
            return None;
        }
        let stop = match self.dead.get(self.di) {
            Some(&d) if d < self.end => d,
            _ => self.end,
        };
        let out = self.cur..stop;
        self.cur = stop;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(s: &IdRangeSet) -> Vec<EdgeId> {
        s.iter().collect()
    }

    #[test]
    fn from_sorted_ids_coalesces_runs() {
        let s = IdRangeSet::from_sorted_ids([0, 1, 2, 5, 6, 9]);
        assert_eq!(s.ranges(), &[0..3, 5..7, 9..10]);
        assert_eq!(s.num_ranges(), 3);
        assert_eq!(s.len(), 6);
        assert_eq!(ids(&s), vec![0, 1, 2, 5, 6, 9]);
    }

    #[test]
    fn membership_and_rank() {
        let s = IdRangeSet::from_sorted_ids([2, 3, 4, 10, 11, 20]);
        for id in [2u64, 3, 4, 10, 11, 20] {
            assert!(s.contains(id), "{id}");
        }
        for id in [0u64, 1, 5, 9, 12, 19, 21, 100] {
            assert!(!s.contains(id), "{id}");
        }
        assert_eq!(s.rank(0), 0);
        assert_eq!(s.rank(2), 0);
        assert_eq!(s.rank(3), 1);
        assert_eq!(s.rank(5), 3);
        assert_eq!(s.rank(10), 3);
        assert_eq!(s.rank(11), 4);
        assert_eq!(s.rank(15), 5);
        assert_eq!(s.rank(21), 6);
        assert_eq!(s.rank(u64::MAX), s.len());
    }

    #[test]
    fn splice_in_merges_touching_neighbours() {
        let mut s = IdRangeSet::from_range(0..5);
        s.splice_in(10..15);
        assert_eq!(s.ranges(), &[0..5, 10..15]);
        // bridge the gap exactly: all three coalesce into one interval
        s.splice_in(5..10);
        assert_eq!(s.ranges(), &[0..15]);
        assert_eq!(s.len(), 15);
        // left-touching only
        s.splice_in(20..22);
        s.splice_in(15..18);
        assert_eq!(s.ranges(), &[0..18, 20..22]);
    }

    #[test]
    fn splice_out_splits_interior_ranges() {
        let mut s = IdRangeSet::from_range(0..20);
        s.splice_out(5..8);
        assert_eq!(s.ranges(), &[0..5, 8..20]);
        assert_eq!(s.len(), 17);
        s.splice_out(0..5); // exact prefix range
        assert_eq!(s.ranges(), &[8..20]);
        s.splice_out(8..10); // prefix of an interval
        assert_eq!(s.ranges(), &[10..20]);
        s.splice_out(15..20); // suffix of an interval
        assert_eq!(s.ranges(), &[10..15]);
        s.splice_out(10..15);
        assert!(s.is_empty());
        assert_eq!(s.num_ranges(), 0);
    }

    #[test]
    fn splice_round_trip_preserves_set() {
        let mut s = IdRangeSet::from_range(0..100);
        s.splice_out(30..60);
        s.splice_in(30..60);
        assert_eq!(s.ranges(), &[0..100]);
        assert_eq!(s.len(), 100);
    }

    #[test]
    #[should_panic(expected = "not wholly owned")]
    fn splice_out_rejects_unowned_ranges() {
        let mut s = IdRangeSet::from_range(0..10);
        s.splice_out(5..15);
    }

    #[test]
    #[should_panic(expected = "not wholly owned")]
    fn splice_out_rejects_ranges_spanning_gaps() {
        let mut s = IdRangeSet::from_sorted_ids([0, 1, 5, 6]);
        s.splice_out(0..7); // spans the hole 2..5
    }

    #[test]
    #[should_panic(expected = "overlaps")]
    fn splice_in_rejects_overlap() {
        let mut s = IdRangeSet::from_range(0..10);
        s.splice_in(8..12);
    }

    #[test]
    fn live_masking() {
        let s = IdRangeSet::from_sorted_ids([0, 1, 2, 3, 10, 11, 12]);
        let dead = vec![1u64, 2, 10, 12];
        assert_eq!(s.live_len(&dead), 3);
        let live: Vec<Range<EdgeId>> = s.live_ranges(&dead).collect();
        assert_eq!(live, vec![0..1, 3..4, 11..12]);
        // dead ids outside the set are ignored
        assert_eq!(s.live_len(&[5, 6, 100]), s.len());
        assert_eq!(s.live_len(&[]), s.len());
    }

    #[test]
    fn live_subranges_of_fully_dead_range() {
        let dead = vec![3u64, 4, 5];
        assert_eq!(live_subranges(3..6, &dead).count(), 0);
        let out: Vec<Range<EdgeId>> = live_subranges(2..7, &dead).collect();
        assert_eq!(out, vec![2..3, 6..7]);
    }

    #[test]
    fn push_back_matches_splice_in() {
        let mut a = IdRangeSet::new();
        let mut b = IdRangeSet::new();
        for id in [3u64, 4, 7, 8, 9, 20] {
            a.push_back(id);
            b.splice_in(id..id + 1);
        }
        assert_eq!(a, b);
        assert_eq!(a.ranges(), &[3..5, 7..10, 20..21]);
    }

    #[test]
    fn empty_and_clear() {
        let mut s = IdRangeSet::from_range(7..7);
        assert!(s.is_empty());
        assert_eq!(s.rank(100), 0);
        assert!(!s.contains(0));
        s.splice_in(1..4);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.num_ranges(), 0);
    }
}
