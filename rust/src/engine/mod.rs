//! A PowerLyra-like distributed graph-processing engine, simulated on one
//! machine: one worker (thread) per edge partition, vertex master/mirror
//! placement, byte-metered mirror exchange (the COM metric of Table 6),
//! and per-partition compute through a [`crate::runtime::ComputeBackend`]
//! (PJRT artifacts in production, native Rust in tests).
//!
//! ## Superstep protocol (vertex-cut GAS)
//!
//! 1. **Scatter**: masters broadcast the current value of every active
//!    vertex to its mirror partitions (metered).
//! 2. **Compute**: each worker runs the app kernel over its local edges
//!    (both directions of each undirected edge) via the backend.
//! 3. **Gather**: workers return per-vertex partial results for their
//!    non-master vertices to the masters (metered).
//! 4. **Apply**: the app combines partials (sum / min) into the new global
//!    state and decides the active set for the next round.

pub mod apps;
pub mod comm;
pub mod mirrors;
pub mod worker;

use crate::graph::Graph;
use crate::partition::EdgePartition;
use crate::runtime::{ComputeBackend, StepKind};
use crate::Result;
use comm::CommMeter;
use mirrors::PartitionLayout;
use worker::Worker;

/// Combine rule of the apply phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    /// sum partials (PageRank contributions)
    Sum,
    /// min partials against current state (SSSP / WCC)
    Min,
}

/// The engine: layout + one worker per partition + a comm meter.
pub struct Engine {
    layout: PartitionLayout,
    workers: Vec<Worker>,
    /// byte/message meter (reset per app run)
    pub comm: CommMeter,
}

impl Engine {
    /// Build from a graph and an edge partitioning. `backend_for` is
    /// invoked once per partition (clone an [`crate::runtime::executor::XlaBackend`]
    /// handle or create fresh [`crate::runtime::native::NativeBackend`]s).
    pub fn new<F>(g: &Graph, part: &EdgePartition, mut backend_for: F) -> Result<Engine>
    where
        F: FnMut(usize) -> Box<dyn ComputeBackend>,
    {
        let layout = PartitionLayout::build(g, part);
        let mut workers = Vec::with_capacity(part.k);
        for p in 0..part.k {
            workers.push(Worker::new(&layout, p, backend_for(p))?);
        }
        Ok(Engine { layout, workers, comm: CommMeter::new() })
    }

    /// Number of partitions.
    pub fn k(&self) -> usize {
        self.workers.len()
    }

    /// The partition layout (mirror placement etc.).
    pub fn layout(&self) -> &PartitionLayout {
        &self.layout
    }

    /// Run one superstep over global state. `active[v]` gates the scatter
    /// phase; returns per-vertex combined partials (Sum) or the improved
    /// state (Min), plus the set of vertices whose value changed.
    pub fn superstep(
        &mut self,
        kind: StepKind,
        combine: Combine,
        state: &[f32],
        aux: &[f32],
        active: &[bool],
    ) -> Result<(Vec<f32>, Vec<bool>)> {
        let n = state.len();
        assert_eq!(n, self.layout.num_vertices());

        // --- 1. scatter: meter master→mirror broadcast of active vertices
        for p in 0..self.workers.len() {
            for &v in self.layout.vertices_of(p) {
                if active[v as usize] && self.layout.master_of(v) != p as u32 {
                    self.comm.record_scatter(8); // 4B id + 4B value
                }
            }
        }

        // --- 2. compute on every worker (serially or via scoped threads;
        // the PJRT actor serializes anyway, and determinism helps tests)
        let mut partials: Vec<Vec<f32>> = Vec::with_capacity(self.workers.len());
        for w in &mut self.workers {
            partials.push(w.compute(kind, state, aux)?);
        }

        // --- 3+4. gather + apply
        let mut out = match combine {
            Combine::Sum => vec![0f32; n],
            Combine::Min => state.to_vec(),
        };
        for (p, partial) in partials.iter().enumerate() {
            for (local, &v) in self.layout.vertices_of(p).iter().enumerate() {
                let x = partial[local];
                match combine {
                    Combine::Sum => {
                        if x != 0.0 {
                            if self.layout.master_of(v) != p as u32 {
                                self.comm.record_gather(8);
                            }
                            out[v as usize] += x;
                        }
                    }
                    Combine::Min => {
                        if x < out[v as usize] {
                            if self.layout.master_of(v) != p as u32 {
                                self.comm.record_gather(8);
                            }
                            out[v as usize] = x;
                        }
                    }
                }
            }
        }
        let changed: Vec<bool> = match combine {
            Combine::Sum => vec![true; n], // PR: all vertices refresh
            Combine::Min => out.iter().zip(state.iter()).map(|(a, b)| a < b).collect(),
        };
        Ok((out, changed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::partition::EdgePartition;
    use crate::runtime::native::NativeBackend;

    fn engine_for_path() -> Engine {
        // path 0-1-2-3, two partitions
        let g = GraphBuilder::new().edge(0, 1).edge(1, 2).edge(2, 3).build();
        let part = EdgePartition::new(2, vec![0, 0, 1]);
        Engine::new(&g, &part, |_| Box::new(NativeBackend::new())).unwrap()
    }

    #[test]
    fn wcc_superstep_propagates_min_labels() {
        let mut e = engine_for_path();
        let state = vec![0.0, 1.0, 2.0, 3.0];
        let aux = vec![0.0; 4];
        let active = vec![true; 4];
        let (out, changed) =
            e.superstep(StepKind::Wcc, Combine::Min, &state, &aux, &active).unwrap();
        assert_eq!(out, vec![0.0, 0.0, 1.0, 2.0]);
        assert_eq!(changed, vec![false, true, true, true]);
        assert!(e.comm.total_bytes() > 0, "boundary vertex must be metered");
    }

    #[test]
    fn pagerank_superstep_conserves_mass() {
        let mut e = engine_for_path();
        // degrees: 1,2,2,1 → invdeg aux
        let state = vec![0.25; 4];
        let aux = vec![1.0, 0.5, 0.5, 1.0];
        let active = vec![true; 4];
        let (out, _) =
            e.superstep(StepKind::PageRank, Combine::Sum, &state, &aux, &active).unwrap();
        let total: f32 = out.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "mass {total}");
    }
}
