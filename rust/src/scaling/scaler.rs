//! `sc(E_k, ±x)` (Def. 3) for the three methods compared in §6.4.3:
//! CEP (ours), BVC (consistent hashing) and 1D (plain rehash).

use crate::partition::bvc::BvcState;
use crate::partition::cep::Cep;
use crate::partition::{hash1d, EdgePartition};
use crate::PartitionId;

/// A dynamic-scaling engine: owns whatever state lets it recompute
/// assignments when `k` changes, and reports the edges that moved.
pub trait DynamicScaler {
    /// Human name for tables.
    fn name(&self) -> &'static str;
    /// Current partition count.
    fn k(&self) -> usize;
    /// Current assignment (edge id → partition).
    fn current(&self) -> EdgePartition;
    /// Rescale to `new_k`; returns the number of migrated edges.
    fn scale_to(&mut self, new_k: usize) -> u64;
}

/// CEP scaler — O(1) metadata recompute; migrated edges are the chunk
/// boundary shifts of Theorem 2.
pub struct CepScaler {
    cep: Cep,
}

impl CepScaler {
    /// Start from `m` ordered edges in `k` chunks.
    pub fn new(m: usize, k: usize) -> CepScaler {
        CepScaler { cep: Cep::new(m, k) }
    }

    /// Access the underlying chunk metadata.
    pub fn cep(&self) -> &Cep {
        &self.cep
    }
}

impl DynamicScaler for CepScaler {
    fn name(&self) -> &'static str {
        "cep"
    }

    fn k(&self) -> usize {
        self.cep.k()
    }

    fn current(&self) -> EdgePartition {
        EdgePartition::from_cep(&self.cep)
    }

    fn scale_to(&mut self, new_k: usize) -> u64 {
        let old = self.cep;
        self.cep = self.cep.rescaled(new_k);
        migration_between_ceps(&old, &self.cep)
    }
}

/// Count edges whose chunk owner differs between two CEP layouts — an
/// O(k+k') sweep over chunk boundaries (not O(m)): between consecutive
/// boundary points the owner pair is constant.
pub fn migration_between_ceps(a: &Cep, b: &Cep) -> u64 {
    assert_eq!(a.num_edges(), b.num_edges());
    let m = a.num_edges();
    if m == 0 {
        return 0;
    }
    // merge the two boundary sets; within each segment both owners fixed
    let mut cuts: Vec<u64> = Vec::with_capacity(a.k() + b.k() + 1);
    for p in 0..=a.k() as u64 {
        cuts.push(crate::partition::cep::chunk_start(m, a.k() as u64, p));
    }
    for p in 0..=b.k() as u64 {
        cuts.push(crate::partition::cep::chunk_start(m, b.k() as u64, p));
    }
    cuts.sort_unstable();
    cuts.dedup();
    let mut moved = 0u64;
    for w in cuts.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        if lo >= m {
            break;
        }
        if a.partition_of(lo) != b.partition_of(lo) {
            moved += hi.min(m) - lo;
        }
    }
    moved
}

/// BVC scaler — wraps [`BvcState`].
pub struct BvcScaler {
    state: BvcState,
}

impl BvcScaler {
    /// Build the ring for `m` edges in `k` partitions.
    pub fn new(m: usize, k: usize, seed: u64) -> BvcScaler {
        BvcScaler { state: BvcState::build(m, k, seed) }
    }

    /// Access refinement statistics of the *last* scale (for Fig 14).
    pub fn state(&self) -> &BvcState {
        &self.state
    }
}

impl DynamicScaler for BvcScaler {
    fn name(&self) -> &'static str {
        "bvc"
    }

    fn k(&self) -> usize {
        self.state.k()
    }

    fn current(&self) -> EdgePartition {
        self.state.to_partition()
    }

    fn scale_to(&mut self, new_k: usize) -> u64 {
        self.state.scale_to(new_k).total_migrated()
    }
}

/// 1D scaler — rehash everything; migrates ~`(1 − 1/k')·m` edges.
pub struct Hash1dScaler {
    m: usize,
    k: usize,
}

impl Hash1dScaler {
    /// `m` edges in `k` partitions.
    pub fn new(m: usize, k: usize) -> Hash1dScaler {
        Hash1dScaler { m, k }
    }
}

impl DynamicScaler for Hash1dScaler {
    fn name(&self) -> &'static str {
        "1d"
    }

    fn k(&self) -> usize {
        self.k
    }

    fn current(&self) -> EdgePartition {
        let assign: Vec<PartitionId> =
            (0..self.m as u64).map(|e| assign_mod(e, self.k)).collect();
        EdgePartition::new(self.k, assign)
    }

    fn scale_to(&mut self, new_k: usize) -> u64 {
        let old_k = self.k;
        self.k = new_k;
        (0..self.m as u64).filter(|&e| assign_mod(e, old_k) != assign_mod(e, new_k)).count()
            as u64
    }
}

#[inline]
fn assign_mod(eid: u64, k: usize) -> PartitionId {
    hash1d::assign_one(eid, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    /// Differential test: the boundary-sweep migration count must equal a
    /// naive per-edge comparison.
    #[test]
    fn cep_migration_matches_naive() {
        check(0x5CA1E, 48, |rng| {
            let m = 100 + rng.below_usize(5000);
            let k0 = 1 + rng.below_usize(40);
            let k1 = 1 + rng.below_usize(40);
            let a = Cep::new(m, k0);
            let b = Cep::new(m, k1);
            let fast = migration_between_ceps(&a, &b);
            let naive = (0..m as u64)
                .filter(|&i| a.partition_of(i) != b.partition_of(i))
                .count() as u64;
            assert_eq!(fast, naive, "m={m} {k0}->{k1}");
        });
    }

    #[test]
    fn cep_scaler_noop_when_k_unchanged() {
        let mut s = CepScaler::new(10_000, 8);
        assert_eq!(s.scale_to(8), 0);
    }

    #[test]
    fn one_d_moves_most_edges() {
        let mut s = Hash1dScaler::new(100_000, 10);
        let moved = s.scale_to(11);
        // expectation: (1 − 1/11)·m ≈ 0.909·m
        let frac = moved as f64 / 100_000.0;
        assert!(frac > 0.85 && frac < 0.95, "frac={frac}");
    }

    #[test]
    fn cep_moves_fewer_than_1d_on_increment() {
        let m = 200_000;
        let mut cep = CepScaler::new(m, 16);
        let mut h1 = Hash1dScaler::new(m, 16);
        let cep_moved = cep.scale_to(17);
        let h1_moved = h1.scale_to(17);
        assert!(
            cep_moved < h1_moved,
            "cep {cep_moved} must move fewer edges than 1d {h1_moved}"
        );
        // Corollary 1: ≈ m/2 for x=1
        let frac = cep_moved as f64 / m as f64;
        assert!(frac > 0.40 && frac < 0.60, "corollary-1 frac={frac}");
    }

    #[test]
    fn scalers_report_consistent_current() {
        let mut s = CepScaler::new(1000, 4);
        s.scale_to(6);
        let p = s.current();
        assert_eq!(p.k, 6);
        assert_eq!(p.assign.len(), 1000);
    }
}
