//! Breadth-first vertex ordering — the shared traversal core used by RCM
//! and a baseline in its own right.

use super::VertexOrdering;
use crate::graph::Graph;
use crate::VertexId;
use std::collections::VecDeque;

/// BFS ordering from vertex 0 (restarting at the smallest unvisited vertex
/// per component), neighbours in ascending id order.
pub fn order(g: &Graph) -> VertexOrdering {
    order_with(g, |_v| 0)
}

/// BFS ordering where neighbour expansion is sorted by `key(v)` then id.
/// RCM passes the vertex degree here.
pub fn order_with<K: Fn(VertexId) -> usize>(g: &Graph, key: K) -> VertexOrdering {
    let n = g.num_vertices();
    let mut visited = vec![false; n];
    let mut perm: Vec<VertexId> = Vec::with_capacity(n);
    let mut queue = VecDeque::new();
    for start in 0..n as VertexId {
        if visited[start as usize] {
            continue;
        }
        visited[start as usize] = true;
        queue.push_back(start);
        while let Some(v) = queue.pop_front() {
            perm.push(v);
            let mut nbrs: Vec<VertexId> = g
                .neighbors(v)
                .map(|(u, _)| u)
                .filter(|&u| !visited[u as usize])
                .collect();
            nbrs.sort_by_key(|&u| (key(u), u));
            nbrs.dedup();
            for u in nbrs {
                if !visited[u as usize] {
                    visited[u as usize] = true;
                    queue.push_back(u);
                }
            }
        }
    }
    VertexOrdering::new(perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn level_order_on_path() {
        let g = GraphBuilder::new().edge(0, 1).edge(1, 2).edge(2, 3).build();
        assert_eq!(order(&g).as_slice(), &[0, 1, 2, 3]);
    }

    #[test]
    fn covers_disconnected_components() {
        let g = GraphBuilder::new().edge(0, 1).edge(2, 3).build();
        assert_eq!(order(&g).as_slice().len(), 4);
    }
}
