"""Pure-jnp oracles for the Pallas kernels and the full model steps.

These are the CORE correctness signal: pytest asserts the Pallas kernels
(`edge_ops`) and the lowered model steps (`model`) match these
implementations across randomized shapes and inputs (hypothesis sweeps in
`python/tests/`).
"""

from __future__ import annotations

import jax.numpy as jnp

from .edge_ops import MASKED


def pr_messages_ref(state, aux, src, mask):
    """Reference PageRank messages."""
    return state[src] * aux[src] * mask


def sssp_messages_ref(state, aux, src, weight, mask):
    """Reference SSSP messages."""
    del aux
    return jnp.where(mask > 0, state[src] + weight, MASKED)


def wcc_messages_ref(state, aux, src, mask):
    """Reference WCC messages."""
    del aux
    return jnp.where(mask > 0, state[src], MASKED)


def pagerank_step_ref(state, aux, src, dst, weight, mask):
    """Reference full PageRank step: scatter-add of messages by dst."""
    del weight
    msgs = pr_messages_ref(state, aux, src, mask)
    return jnp.zeros_like(state).at[dst].add(msgs)


def sssp_step_ref(state, aux, src, dst, weight, mask):
    """Reference full SSSP step: scatter-min of messages against state."""
    msgs = sssp_messages_ref(state, aux, src, weight, mask)
    relaxed = jnp.full_like(state, MASKED).at[dst].min(msgs)
    return jnp.minimum(state, relaxed)


def wcc_step_ref(state, aux, src, dst, weight, mask):
    """Reference full WCC step."""
    del weight
    msgs = wcc_messages_ref(state, aux, src, mask)
    relaxed = jnp.full_like(state, MASKED).at[dst].min(msgs)
    return jnp.minimum(state, relaxed)
