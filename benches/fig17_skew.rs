//! Fig 17 (extension) — skew-aware rebalancing: steady-state PageRank on a
//! power-law graph under uniform CEP chunks vs threshold boundary nudging.
//!
//! The uniform chunk grid balances *edge counts*, not *cost*: on skewed
//! graphs the communication lanes of a few partitions dominate the
//! superstep. The threshold policy meters per-partition cost
//! (modeled ns/edge compute + comm-lane bytes), re-solves balanced
//! boundaries by prefix-sum, and nudges them with ≤ 2(k−1) contiguous
//! moves priced through the network model.
//!
//! Expected shape: nudged runs end with lower metered max/mean imbalance
//! than uniform CEP, at a rebalance cost that is a small fraction of APP;
//! under the emulator (overlap mode) part of the nudge traffic hides
//! behind the superstep and only the blocking share is charged.

mod common;

use common::BenchLog;
use egs::coordinator::{Controller, PolicyConfig, RunConfig};
use egs::metrics::table::{secs, Table};
use egs::ordering::geo::{self, GeoConfig};
use egs::runtime::native::NativeBackend;
use egs::scaling::netsim::{NetModelConfig, NetworkModel};
use egs::scaling::scenario::Scenario;

fn main() {
    let dataset = "pokec-s";
    let g = common::dataset(dataset);
    let ordered = geo::order(&g, &GeoConfig::default()).apply(&g);
    let iters = common::scaled(20, 8) as u32;
    let scenario = Scenario::steady(6, iters);
    let mut log = BenchLog::new("fig17");

    let mut t = Table::new(
        &format!("Fig 17: skew-aware rebalancing, PageRank {} on {dataset}", scenario.name),
        &["policy", "ALL", "APP", "REBAL", "NET", "imbalance", "nudges", "moved"],
    );
    // uniform CEP baseline, then the threshold policy priced closed-form
    // and under the discrete-event emulator (overlap mode)
    let light = NetModelConfig { compute_ns_per_edge: 0.1, ..Default::default() };
    let light_emu = NetModelConfig { compute_ns_per_edge: 0.1, ..NetModelConfig::emulated() };
    for (label, net_model, threshold) in [
        ("uniform", light, None),
        ("nudged", light, Some(1.05)),
        ("nudged (emu)", light_emu, Some(1.05)),
    ] {
        let policy = match threshold {
            Some(t) => PolicyConfig::Threshold { threshold: t },
            None => PolicyConfig::Off,
        };
        let cfg = RunConfig::new().method("cep").net_model(net_model).policy(policy);
        let out = Controller::drive(ordered.clone(), &scenario, &cfg, |_| {
            Box::new(NativeBackend::new())
        })
        .unwrap();
        let moved: u64 = out.rebalances.iter().map(|r| r.moved_edges).sum();
        t.row(vec![
            label.to_string(),
            secs(out.all_s),
            secs(out.app_s),
            secs(out.rebalance_s),
            secs(out.net_s),
            format!("{:.3}", out.final_imbalance),
            out.rebalances.len().to_string(),
            moved.to_string(),
        ]);
        let scenario_key = match (threshold.is_some(), net_model.model) {
            (true, NetworkModel::Emulated) => "nudged-emulated/steady",
            (true, _) => "nudged/steady",
            (false, _) => "uniform/steady",
        };
        let rebalance_ms = threshold.map(|_| out.rebalance_s * 1e3);
        log.record(scenario_key, out.all_s * 1e3)
            .layout(out.layout_ranges as u64, out.layout_bytes as u64)
            .net(net_model.model.name(), out.net_s * 1e3)
            .rebalance(out.final_imbalance, rebalance_ms)
            .latency(out.superstep_p50_ms, out.superstep_p99_ms);
    }
    t.print();
    log.finish();
    println!(
        "expected: nudged ends with lower metered imbalance than uniform CEP;\n\
         every nudge is at most 2(k-1) contiguous moves, and under emulation\n\
         only the blocking share of the nudge traffic is charged to REBAL"
    );
}
